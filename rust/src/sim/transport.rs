//! Discrete-event learner pool: a [`ControllerTransport`] whose
//! learners are event-driven models instead of threads.
//!
//! ## How a task flows
//!
//! When the controller sends a [`CtrlMsg::Task`], the simulated
//! learner's **numerics run immediately** (the same
//! [`LearnerBackend`] update the threaded learner would run, so the
//! recovered parameters are bit-compatible with a real run), but its
//! **time cost is modeled**: the coded result is scheduled on a
//! binary-heap event queue at
//!
//! ```text
//! t_ready = now + workload · compute_per_update + injected_delay
//! ```
//!
//! in virtual nanoseconds. `recv_timeout` pops the earliest event,
//! advances the shared [`VirtualClock`] to its timestamp, and hands
//! the controller the [`LearnerMsg::Result`] — so a sweep with
//! 250 ms injected delays costs 250 virtual ms and ~zero wall ms.
//!
//! ## System model (PR 5)
//!
//! Compute and network time come from a pluggable
//! [`crate::model::SystemModel`]:
//!
//! * the per-update cost is a [`crate::model::ComputeModel`] — the
//!   fixed `mock_compute` constant (default, bit-identical to the old
//!   hardcoded path) or an empirical calibrated distribution;
//! * message transfer runs through a [`crate::model::NetworkModel`]:
//!   a Task delivery costs the **shared body once per broadcast**
//!   (PR 4's split frame — every learner of one iteration waits the
//!   same body transfer, as over a serialize-once uplink) plus its
//!   small per-learner header, and the result return costs the Result
//!   frame (recorded as traffic only when actually delivered — a
//!   cancelled result was never sent by the real learner). With the
//!   default free network nothing is charged and no RNG is consumed. Payload sizes come from the exact wire-length
//!   queries (`TaskBody::wire_len` & friends), never from forcing an
//!   encode. On the flat default topology acks stay free: they are
//!   tiny and charging them would only delay cancellations the real
//!   transport performs eagerly.
//!
//! ## Per-link topology + incast (PR 10)
//!
//! Under `--topology racks:<r>x<w>` the Result **return leg** is no
//! longer resolved at scheduling time. The event's heap timestamp is
//! `t_base` — compute done plus the jitter draw, the instant the
//! frame *starts* transmitting — and the pop path runs the FCFS queue
//! walk of [`crate::model::NetworkModel::racked_walk`]: serialization
//! over the learner's rack uplink (`--uplink-mbps`), then over the
//! controller ingress link (the base `--bandwidth`), each behind
//! whatever frame committed before it. Simultaneous returns therefore
//! **queue** (incast) instead of teleporting past each other. A pop
//! refused by the caller's deadline commits no busy state, so the
//! walk replays identically on the next call; an omitted result still
//! occupies both links (it was transmitted, then dropped at the
//! controller). Racked acks are charged as broadcast-leg traffic
//! (accounting only — cancellation stays synchronous, as the real
//! transport sends acks eagerly).
//!
//! An [`CtrlMsg::Ack`] cancels the acknowledged iteration's still
//! pending results (generation counters; lazy heap deletion), exactly
//! like the threaded learner aborting its delay wait when the
//! controller has already recovered θ'. If no event is pending,
//! `recv_timeout` charges the full timeout window to virtual time so
//! the controller's deadline arithmetic behaves as in real time.
//!
//! Determinism: with the mock backend the event times are pure
//! functions of (config, seed) and ties break by send order, so two
//! runs of the same config produce **bit-identical** results *and*
//! timing telemetry — the property `rust/tests/sim_integration.rs`
//! pins.

use std::cmp::Ordering as CmpOrdering;
use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use super::clock::{Clock, ClockRef, VirtualClock};
use crate::coordinator::backend::{LearnerBackend, MockBackend};
use crate::linalg::kernels;
use crate::linalg::pool::BufPool;
use crate::marl::ModelDims;
use crate::model::{CorruptionDirective, FaultPlan, NetStats, SystemModel};
use crate::obs::{Event as ObsEvent, Tracer, WasteStats};
use crate::transport::msg::{ack_wire_len, result_wire_len, task_header_wire_len};
use crate::transport::{ControllerTransport, CtrlMsg, LearnerMsg, TaskBody};

/// A scheduled learner reply. Orders as a **min**-heap entry on
/// (virtual time, send sequence) under `BinaryHeap`'s max-heap.
struct Event {
    at: Duration,
    seq: u64,
    learner: usize,
    generation: u64,
    /// Modeled return-leg transfer inside `at`, recorded into the
    /// network stats only if the result is actually **delivered** — a
    /// cancelled (acked/superseded) result was never sent by the real
    /// learner, so its frame must not count as traffic.
    net_out: Duration,
    /// Injected omission: the learner really computed and sent this
    /// result (compute + return leg are charged) but it is dropped in
    /// flight instead of delivered.
    omitted: bool,
    /// Racked topology only: the Result frame's wire length, resolved
    /// through the FCFS per-link walk at **pop** time (`at` is then
    /// `t_base`, the instant transmission starts). Zero on the flat
    /// path, where the return leg is already inside `at`/`net_out`.
    ret_bytes: usize,
    msg: LearnerMsg,
}

impl PartialEq for Event {
    fn eq(&self, other: &Event) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Event) -> Option<CmpOrdering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Event) -> CmpOrdering {
        // Reversed: the earliest event must pop first; equal times pop
        // in send order (deterministic tie-break).
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// One simulated learner: real numerics, modeled time.
struct SimLearner {
    /// `None` models a learner whose backend failed to construct — a
    /// permanent erasure, mirroring the threaded pool's dead-learner
    /// semantics (tasks are swallowed, no result ever arrives).
    backend: Option<Box<dyn LearnerBackend>>,
    /// Bumped to invalidate this learner's scheduled event (on a new
    /// Task or a covering Ack).
    generation: u64,
    /// Iteration of the scheduled-but-undelivered result, if any.
    pending_iter: Option<u64>,
    /// Injected crash: down until this virtual instant
    /// (`Duration::MAX` = permanent). Checked — and lazily cleared
    /// once elapsed — at task receipt.
    down_until: Option<Duration>,
}

/// Event-driven [`ControllerTransport`] over a [`VirtualClock`].
pub struct SimTransport {
    clock: Arc<VirtualClock>,
    learners: Vec<SimLearner>,
    events: BinaryHeap<Event>,
    seq: u64,
    /// Gradient-buffer free list shared with the controller
    /// ([`ControllerTransport::buf_pool`]): result vectors are taken
    /// here and return after decode (or when a cancelled event is
    /// lazily popped); assignment rows return the moment their task is
    /// absorbed. Steady state: zero per-iteration heap allocation.
    pool: Arc<BufPool>,
    /// Compute-cost + network-transfer models (module docs §System
    /// model). Default: fixed compute, free network.
    model: SystemModel,
    /// The iteration whose shared-body transfer has been charged, and
    /// its memoized transfer time — every learner of one broadcast
    /// waits the same body leg.
    net_iter: Option<u64>,
    net_body_time: Duration,
    /// Run tracer shared with the controller
    /// ([`ControllerTransport::set_tracer`]); disabled by default.
    tracer: Arc<Tracer>,
    /// Wasted work only the transport can see: results cancelled while
    /// in flight (acked / superseded before delivery). Always counted
    /// — it is a pure accumulator over values the cancellation path
    /// already holds.
    waste: WasteStats,
    /// Learners whose result for `omit_iter` is dropped in flight
    /// (installed by [`ControllerTransport::inject_faults`]).
    omit_iter: Option<u64>,
    omit: Vec<usize>,
    /// Corruption directives for `corrupt_iter` (installed by
    /// [`ControllerTransport::inject_faults`]): the result is
    /// delivered **perturbed**, not dropped — a corrupted learner is
    /// not `lost`, because only the verified decoder can tell.
    corrupt_iter: Option<u64>,
    corrupt: Vec<CorruptionDirective>,
    /// Learners known lost for `lost_iter` — crashed at task receipt,
    /// dead backend, or omitted result — recorded at *scheduling*
    /// time so [`ControllerTransport::lost_for_iter`] lets the
    /// controller fail fast instead of idling to its collect timeout.
    /// Stale iterations are ignored by the iter check, so no
    /// per-iteration reset is needed; fault-free runs never push here
    /// beyond dead-backend erasures.
    lost_iter: Option<u64>,
    lost: Vec<usize>,
}

impl SimTransport {
    /// `n` simulated learners with deterministic mock numerics and
    /// `compute` virtual time per agent update (the virtual-mode
    /// counterpart of `TrainConfig::mock_compute`).
    pub fn new(n: usize, dims: ModelDims, compute: Duration) -> SimTransport {
        let backends = (0..n)
            .map(|_| Box::new(MockBackend::new(dims, Duration::ZERO)) as Box<dyn LearnerBackend>)
            .collect();
        SimTransport::with_backends(backends, compute)
    }

    /// Simulated learners over backends built by the caller's factory
    /// — the same factory contract `spawn_local` honors, so tests with
    /// instrumented or failing factories behave identically in virtual
    /// time. A factory error makes that learner a permanent erasure
    /// (logged, not fatal), exactly like a learner thread that dies at
    /// startup — except when **every** backend fails, which is a
    /// backend/artifacts misconfiguration (e.g. PJRT without
    /// artifacts) and errors up front instead of masquerading as N
    /// stragglers that later trip the collect timeout.
    pub fn from_factory(
        n: usize,
        factory: &crate::coordinator::backend::BackendFactory,
        compute: Duration,
    ) -> Result<SimTransport> {
        SimTransport::from_factory_with_model(n, factory, SystemModel::fixed(compute))
    }

    /// [`SimTransport::from_factory`] with an explicit
    /// [`SystemModel`] — the path [`crate::coordinator::spawn_pool`]
    /// takes when the config asks for calibrated compute or a modeled
    /// network.
    pub fn from_factory_with_model(
        n: usize,
        factory: &crate::coordinator::backend::BackendFactory,
        model: SystemModel,
    ) -> Result<SimTransport> {
        let backends: Vec<Option<Box<dyn LearnerBackend>>> = (0..n)
            .map(|id| match factory(id as u32) {
                Ok(b) => Some(b),
                Err(e) => {
                    crate::log_error!(
                        "sim learner {id}: backend construction failed: {e:#}; \
                         treating as permanent erasure"
                    );
                    None
                }
            })
            .collect();
        if n > 0 && backends.iter().all(|b| b.is_none()) {
            bail!(
                "all {n} simulated learner backends failed to construct — this is a \
                 backend/artifacts misconfiguration (see the errors above), not a \
                 straggler scenario"
            );
        }
        Ok(SimTransport::assemble(backends, model))
    }

    /// Custom backends. Their wall time is modeled by `compute`.
    pub fn with_backends(
        backends: Vec<Box<dyn LearnerBackend>>,
        compute: Duration,
    ) -> SimTransport {
        SimTransport::with_backends_and_model(backends, SystemModel::fixed(compute))
    }

    /// Custom backends with an explicit [`SystemModel`].
    pub fn with_backends_and_model(
        backends: Vec<Box<dyn LearnerBackend>>,
        model: SystemModel,
    ) -> SimTransport {
        SimTransport::assemble(backends.into_iter().map(Some).collect(), model)
    }

    fn assemble(
        mut backends: Vec<Option<Box<dyn LearnerBackend>>>,
        model: SystemModel,
    ) -> SimTransport {
        // Redirect every backend's *emulated* time spending onto a
        // detached sink clock: its sleeps become instant and wall-free
        // while the sim charges `compute` per update on the real event
        // clock — no double counting, and no constructor can smuggle a
        // really-sleeping backend into a "hardware-speed" sweep.
        let sink: ClockRef = Arc::new(VirtualClock::new());
        for backend in backends.iter_mut().flatten() {
            backend.set_clock(sink.clone());
        }
        let learners: Vec<SimLearner> = backends
            .into_iter()
            .map(|backend| SimLearner {
                backend,
                generation: 0,
                pending_iter: None,
                down_until: None,
            })
            .collect();
        // Each learner carries at most one live event plus a bounded
        // number of lazily-deleted stale ones; pre-sizing avoids heap
        // regrowth inside N = 1000-learner iterations.
        let events = BinaryHeap::with_capacity(2 * learners.len() + 1);
        // Shelf cap sized to one iteration's working set: N assignment
        // rows + up to 2N result vectors in flight (pending + just
        // recycled) + M ≤ N flat parameter vectors from the controller.
        let pool = Arc::new(BufPool::with_shelf_cap(3 * learners.len() + 8));
        SimTransport {
            clock: VirtualClock::shared(),
            learners,
            events,
            seq: 0,
            pool,
            model,
            net_iter: None,
            net_body_time: Duration::ZERO,
            tracer: Tracer::disabled(),
            waste: WasteStats::default(),
            omit_iter: None,
            omit: Vec::new(),
            corrupt_iter: None,
            corrupt: Vec::new(),
            lost_iter: None,
            lost: Vec::new(),
        }
    }

    /// Whether learner `j` is crashed at `now`, lazily clearing an
    /// elapsed restart.
    fn is_down(&mut self, j: usize, now: Duration) -> bool {
        match self.learners[j].down_until {
            Some(until) if now < until => true,
            Some(_) => {
                self.learners[j].down_until = None;
                false
            }
            None => false,
        }
    }

    /// Record learner `j` as lost for `iter` (crash-swallowed task,
    /// dead backend, or omitted result).
    fn mark_lost(&mut self, iter: u64, j: usize) {
        if self.lost_iter != Some(iter) {
            self.lost_iter = Some(iter);
            self.lost.clear();
        }
        if !self.lost.contains(&j) {
            self.lost.push(j);
        }
    }

    /// The transport's virtual clock (also returned, type-erased, by
    /// [`ControllerTransport::clock`]).
    pub fn virtual_clock(&self) -> Arc<VirtualClock> {
        Arc::clone(&self.clock)
    }

    /// Broadcast-leg network charge for one Task: the shared body once
    /// per iteration (memoized — every learner of the broadcast waits
    /// the same body transfer) plus this learner's small header. Free
    /// network: zero, no RNG, no size query.
    fn charge_broadcast(&mut self, iter: u64, body: &TaskBody, row_len: usize) -> Duration {
        if self.model.network.is_free() {
            return Duration::ZERO;
        }
        let body_time = if self.net_iter == Some(iter) {
            self.net_body_time
        } else {
            let t = self.model.network.transfer(body.wire_len());
            self.model.network.record_broadcast(t, true);
            self.net_iter = Some(iter);
            self.net_body_time = t;
            t
        };
        let header = self.model.network.transfer(task_header_wire_len(row_len));
        self.model.network.record_broadcast(header, false);
        body_time + header
    }

    /// Return-leg transfer time for one Result frame of `p` floats.
    /// Drawn (jitter) at scheduling time so RNG order is the
    /// deterministic send order, but **recorded** into the stats only
    /// on delivery (see [`Event::net_out`]).
    ///
    /// Flat topology: the full serialization + jitter, folded into the
    /// event time (`ret_bytes` 0). Racked topology: only the jitter is
    /// drawn here (same RNG order as flat — one draw per scheduled
    /// result); serialization and queueing resolve at pop time through
    /// the FCFS walk, so the second element carries the frame's wire
    /// length.
    fn return_leg(&mut self, p: usize) -> (Duration, usize) {
        if self.model.network.is_racked() {
            // transfer(0) serializes zero bytes: a pure jitter draw.
            return (self.model.network.transfer(0), result_wire_len(p));
        }
        if self.model.network.is_free() {
            return (Duration::ZERO, 0);
        }
        (self.model.network.transfer(result_wire_len(p)), 0)
    }

    /// Run the learner's coded update now, schedule its result at the
    /// modeled completion time
    ///
    /// ```text
    /// t_ready = now + net_in + compute + injected_delay + net_out
    /// ```
    ///
    /// (network legs zero under the default free model; under a racked
    /// topology `net_out` is the jitter draw only and the event time is
    /// `t_base`, the instant the return frame starts transmitting —
    /// serialization + queueing resolve at pop time). The accumulator
    /// comes from the shared [`BufPool`] (recycled from previously
    /// decoded results), and the absorbed assignment row goes straight
    /// back to it.
    fn handle_task(
        &mut self,
        j: usize,
        iter: u64,
        epoch: u16,
        row: Vec<f32>,
        body: &TaskBody,
        straggler_delay_ns: u64,
    ) -> Result<()> {
        let now = self.clock.now();
        self.learners[j].generation += 1; // a new task supersedes any pending result
        let net_in = self.charge_broadcast(iter, body, row.len());
        if self.learners[j].backend.is_none() || self.is_down(j, now) {
            // Permanent erasure (dead backend) or injected crash: the
            // task is swallowed — and the loss is visible to the
            // controller via `lost_for_iter`, so collect fails fast
            // instead of waiting out its timeout.
            self.pool.put(row);
            self.mark_lost(iter, j);
            return Ok(());
        }
        let p = body.agent_params.first().map(|v| v.len()).unwrap_or(0);
        let (net_out, ret_bytes) = self.return_leg(p);
        let mut y = self.pool.take_zeroed(p);
        let learner = &mut self.learners[j];
        let backend = learner.backend.as_mut().expect("checked above");
        let mut updates = 0u32;
        for (i, &c) in row.iter().enumerate() {
            if c == 0.0 {
                continue;
            }
            let theta_i = backend.update_agent(i, &body.agent_params, &body.minibatch)?;
            kernels::axpy(&mut y, c, &theta_i);
            updates += 1;
        }
        let compute = self.model.compute.cost(updates);
        let at = now + net_in + compute + Duration::from_nanos(straggler_delay_ns) + net_out;
        learner.pending_iter = Some(iter);
        let generation = learner.generation;
        self.pool.put(row);
        // Injected corruption: the learner computed the honest result,
        // but what arrives is silently perturbed. Applied after the
        // numerics (backend state and RNG streams untouched) and NOT
        // marked lost — detecting it is the verified decoder's job.
        if self.corrupt_iter == Some(iter) {
            if let Some(d) = self.corrupt.iter().find(|d| d.learner == j) {
                d.apply(&mut y);
                let mode = d.mode.name();
                self.tracer.record(|| ObsEvent::CorruptionInjected {
                    iter,
                    learner: j as u32,
                    mode,
                });
            }
        }
        // Injected omission: the learner computes and transmits as
        // usual, but the result is dropped in flight. Marked lost at
        // scheduling time so the controller never waits on it.
        let omitted = self.omit_iter == Some(iter) && self.omit.contains(&j);
        if omitted {
            self.mark_lost(iter, j);
        }
        self.seq += 1;
        self.events.push(Event {
            at,
            seq: self.seq,
            learner: j,
            generation,
            net_out,
            omitted,
            ret_bytes,
            msg: LearnerMsg::Result {
                iter,
                epoch,
                learner_id: j as u32,
                y,
                compute_ns: u64::try_from(compute.as_nanos()).unwrap_or(u64::MAX),
            },
        });
        Ok(())
    }

    /// θ' for `iter` is recovered: the learner aborts, so its not yet
    /// delivered result never materializes.
    fn handle_ack(&mut self, j: usize, iter: u64) {
        // Racked topology: the tiny Ack frame is charged as
        // broadcast-leg traffic (accounting only). The cancellation
        // below stays synchronous — the real transport sends acks
        // eagerly, and delaying them would only waste learner work.
        if self.model.network.is_racked() {
            let t = self.model.network.transfer(ack_wire_len());
            self.model.network.record_ack(t);
        }
        let learner = &mut self.learners[j];
        if learner.pending_iter.is_some_and(|pending| pending <= iter) {
            learner.generation += 1;
            learner.pending_iter = None;
        }
    }
}

impl ControllerTransport for SimTransport {
    fn n_learners(&self) -> usize {
        self.learners.len()
    }

    fn send_to(&mut self, learner: usize, msg: CtrlMsg) -> Result<()> {
        match msg {
            CtrlMsg::Task { iter, epoch, row, body, straggler_delay_ns } => {
                self.handle_task(learner, iter, epoch, row, &body, straggler_delay_ns)
            }
            CtrlMsg::Ack { iter } => {
                self.handle_ack(learner, iter);
                Ok(())
            }
            CtrlMsg::Shutdown | CtrlMsg::Welcome { .. } => Ok(()),
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<LearnerMsg>> {
        let deadline = self.clock.now() + timeout;
        while let Some(top) = self.events.peek() {
            if top.generation != self.learners[top.learner].generation {
                // Cancelled (superseded task / acked iteration): its
                // result vector goes back to the pool instead of the
                // allocator, and its bytes/compute count as waste —
                // the threaded learner would have burned them too
                // before noticing the ack.
                if let Some(Event { msg: LearnerMsg::Result { iter, learner_id, y, compute_ns, .. }, .. }) =
                    self.events.pop()
                {
                    let bytes = result_wire_len(y.len()) as u64;
                    self.waste.add(bytes, compute_ns);
                    self.tracer.record(|| ObsEvent::ResultCancelled {
                        iter,
                        learner: learner_id,
                        bytes,
                        compute_ns,
                    });
                    self.pool.put(y);
                }
                continue;
            }
            // Effective arrival: the heap time on the flat path; on a
            // racked path, a *peek* of the FCFS walk from
            // t_base = top.at — no busy state is mutated, so a
            // deadline refusal replays the identical walk next call.
            let arrival = if top.ret_bytes > 0 {
                let rack = self.model.network.rack_of(top.learner);
                self.model.network.racked_walk(rack, top.ret_bytes, top.at).0
            } else {
                top.at
            };
            if arrival > deadline {
                // The next reply lands beyond the caller's window: a
                // real transport would time out first, so the sim must
                // too (the event stays queued for a later call).
                self.clock.advance_to(deadline);
                return Ok(None);
            }
            let ev = self.events.pop().expect("peeked event");
            let mut queued_ns = 0u64;
            if ev.ret_bytes > 0 {
                // Commit the walk: this frame now occupies its rack
                // uplink and the controller ingress, pushing later
                // frames behind it (incast). The recorded return time
                // is the whole t_base → arrival span plus the jitter
                // already inside `at`.
                let rack = self.model.network.rack_of(ev.learner);
                let (arrival, queued) =
                    self.model.network.commit_racked_walk(rack, ev.ret_bytes, ev.at);
                self.clock.advance_to(arrival);
                self.model.network.record_return(ev.net_out + (arrival - ev.at));
                queued_ns = u64::try_from(queued.as_nanos()).unwrap_or(u64::MAX);
            } else {
                self.clock.advance_to(ev.at);
                if !ev.net_out.is_zero() {
                    self.model.network.record_return(ev.net_out);
                }
            }
            self.learners[ev.learner].pending_iter = None;
            if ev.omitted {
                // Dropped in flight: the learner really computed and
                // transmitted (return leg + compute are charged as
                // waste, links occupied), but the controller never
                // sees the frame.
                if let LearnerMsg::Result { iter, learner_id, y, compute_ns, .. } = ev.msg {
                    let bytes = result_wire_len(y.len()) as u64;
                    self.waste.add(bytes, compute_ns);
                    self.tracer.record(|| ObsEvent::ResultCancelled {
                        iter,
                        learner: learner_id,
                        bytes,
                        compute_ns,
                    });
                    self.pool.put(y);
                }
                continue;
            }
            if self.tracer.is_enabled() {
                if let LearnerMsg::Result { iter, learner_id, ref y, .. } = ev.msg {
                    if queued_ns > 0 {
                        self.tracer.record(|| ObsEvent::IngressQueued {
                            iter,
                            learner: learner_id,
                            queued_ns,
                        });
                    }
                    let bytes = result_wire_len(y.len()) as u64;
                    self.tracer.record(|| ObsEvent::FrameRecv { learner: learner_id, bytes });
                }
            }
            return Ok(Some(ev.msg));
        }
        // Nothing in flight: the wait can only end by timeout, so the
        // whole window elapses in virtual time.
        self.clock.advance_to(deadline);
        Ok(None)
    }

    fn shutdown(&mut self) {
        self.events.clear();
    }

    fn clock(&self) -> ClockRef {
        self.clock.clone()
    }

    fn buf_pool(&self) -> Option<Arc<BufPool>> {
        Some(Arc::clone(&self.pool))
    }

    fn net_stats(&self) -> Option<NetStats> {
        Some(self.model.network.stats())
    }

    fn set_tracer(&mut self, tracer: Arc<Tracer>) {
        self.tracer = tracer;
    }

    fn waste_stats(&self) -> Option<WasteStats> {
        Some(self.waste)
    }

    fn inject_faults(&mut self, iter: u64, plan: &FaultPlan) {
        let now = self.clock.now();
        for &(j, down_ns) in &plan.crashes {
            if j >= self.learners.len() || self.is_down(j, now) {
                continue; // already down: the directive is moot
            }
            let until = match down_ns {
                Some(ns) => now + Duration::from_nanos(ns),
                None => Duration::MAX, // permanent
            };
            let learner = &mut self.learners[j];
            learner.down_until = Some(until);
            // The crash kills any in-flight result (lazy heap delete,
            // same mechanism as an ack — its waste is counted when the
            // stale event pops).
            learner.generation += 1;
            learner.pending_iter = None;
            self.tracer.record(|| ObsEvent::CrashInjected {
                iter,
                learner: j as u32,
                down_ns,
            });
        }
        self.omit_iter = Some(iter);
        self.omit.clear();
        self.omit.extend_from_slice(&plan.omissions);
        self.corrupt_iter = Some(iter);
        self.corrupt.clear();
        self.corrupt.extend_from_slice(&plan.corruptions);
    }

    fn lost_for_iter(&self, iter: u64) -> Option<&[usize]> {
        (self.lost_iter == Some(iter) && !self.lost.is_empty())
            .then(|| self.lost.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::marl::buffer::Minibatch;
    use crate::marl::AgentParams;
    use crate::rng::Pcg32;

    fn dims() -> ModelDims {
        ModelDims { m: 3, obs_dim: 4, act_dim: 2, hidden: 8, batch: 4 }
    }

    fn task(
        iter: u64,
        row: Vec<f32>,
        delay_ns: u64,
        rng: &mut Pcg32,
    ) -> (CtrlMsg, Vec<Vec<f32>>, Minibatch) {
        let d = dims();
        let params: Vec<Vec<f32>> =
            (0..d.m).map(|_| AgentParams::init(&d, rng).to_flat()).collect();
        let mb = Minibatch {
            batch: d.batch,
            m: d.m,
            obs_dim: d.obs_dim,
            act_dim: d.act_dim,
            obs: rng.normal_vec_f32(d.batch * d.m * d.obs_dim, 1.0),
            act: rng.normal_vec_f32(d.batch * d.m * d.act_dim, 1.0),
            rew: rng.normal_vec_f32(d.m * d.batch, 1.0),
            next_obs: rng.normal_vec_f32(d.batch * d.m * d.obs_dim, 1.0),
            done: vec![0.0; d.batch],
        };
        (
            CtrlMsg::Task {
                iter,
                epoch: 0,
                row,
                body: crate::transport::TaskBody::new(
                    Arc::new(params.clone()),
                    Arc::new(mb.clone()),
                ),
                straggler_delay_ns: delay_ns,
            },
            params,
            mb,
        )
    }

    #[test]
    fn result_carries_the_coded_combination() {
        let mut sim = SimTransport::new(1, dims(), Duration::from_millis(2));
        let mut rng = Pcg32::seeded(0);
        let (msg, params, mb) = task(1, vec![2.0, 0.0, -1.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { iter, y, compute_ns, .. } = got else { panic!("want Result") };
        assert_eq!(iter, 1);
        // two nonzero coefficients → 2 modeled updates
        assert_eq!(compute_ns, 4_000_000);
        let mut be = MockBackend::new(dims(), Duration::ZERO);
        let t0 = be.update_agent(0, &params, &mb).unwrap();
        let t2 = be.update_agent(2, &params, &mb).unwrap();
        for k in 0..y.len() {
            let want = 2.0 * t0[k] - t2[k];
            assert!((y[k] - want).abs() < 1e-5, "k={k}: {} vs {want}", y[k]);
        }
    }

    /// Simulated learners echo the task's coding-plan epoch on the
    /// result, exactly as the threaded/TCP learner loop does — the
    /// controller's stale-epoch classification depends on it.
    #[test]
    fn result_echoes_the_task_epoch() {
        let mut sim = SimTransport::new(1, dims(), Duration::ZERO);
        let mut rng = Pcg32::seeded(40);
        let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
        let CtrlMsg::Task { iter, row, body, straggler_delay_ns, .. } = msg else {
            unreachable!()
        };
        sim.send_to(0, CtrlMsg::Task { iter, epoch: 5, row, body, straggler_delay_ns })
            .unwrap();
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { epoch, .. } = got else { panic!() };
        assert_eq!(epoch, 5, "the result must echo the task's plan epoch");
    }

    #[test]
    fn events_arrive_in_virtual_time_order() {
        let mut sim = SimTransport::new(2, dims(), Duration::from_millis(10));
        let mut rng = Pcg32::seeded(1);
        // learner 0: 1 update + 100ms delay → ready at 110ms
        // learner 1: 3 updates, no delay     → ready at  30ms
        let (t0, _, _) = task(1, vec![1.0, 0.0, 0.0], 100_000_000, &mut rng);
        let (t1, _, _) = task(1, vec![1.0, 1.0, 1.0], 0, &mut rng);
        sim.send_to(0, t0).unwrap();
        sim.send_to(1, t1).unwrap();
        let first = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { learner_id, .. } = first else { panic!() };
        assert_eq!(learner_id, 1);
        assert_eq!(sim.virtual_clock().now(), Duration::from_millis(30));
        let second = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { learner_id, .. } = second else { panic!() };
        assert_eq!(learner_id, 0);
        assert_eq!(sim.virtual_clock().now(), Duration::from_millis(110));
    }

    #[test]
    fn ack_cancels_pending_result() {
        let mut sim = SimTransport::new(1, dims(), Duration::from_millis(1));
        let mut rng = Pcg32::seeded(2);
        let (msg, _, _) = task(7, vec![1.0, 0.0, 0.0], 50_000_000, &mut rng);
        sim.send_to(0, msg).unwrap();
        sim.send_to(0, CtrlMsg::Ack { iter: 7 }).unwrap();
        let quiet = sim.recv_timeout(Duration::from_millis(200)).unwrap();
        assert!(quiet.is_none(), "acked result must not be delivered: {quiet:?}");
        // the learner stays healthy for the next iteration
        let (msg2, _, _) = task(8, vec![0.0, 1.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg2).unwrap();
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { iter, .. } = got else { panic!() };
        assert_eq!(iter, 8);
    }

    #[test]
    fn stale_ack_does_not_cancel_newer_task() {
        let mut sim = SimTransport::new(1, dims(), Duration::from_millis(1));
        let mut rng = Pcg32::seeded(3);
        let (msg, _, _) = task(5, vec![0.0, 1.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        sim.send_to(0, CtrlMsg::Ack { iter: 4 }).unwrap(); // older iteration
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { iter, .. } = got else { panic!() };
        assert_eq!(iter, 5);
    }

    #[test]
    fn empty_queue_times_out_in_virtual_time() {
        let mut sim = SimTransport::new(1, dims(), Duration::ZERO);
        let before = sim.virtual_clock().now();
        let got = sim.recv_timeout(Duration::from_secs(7)).unwrap();
        assert!(got.is_none());
        assert_eq!(sim.virtual_clock().now(), before + Duration::from_secs(7));
    }

    #[test]
    fn zero_row_completes_instantly_with_zero_vector() {
        let mut sim = SimTransport::new(1, dims(), Duration::from_millis(10));
        let mut rng = Pcg32::seeded(4);
        let (msg, params, _) = task(1, vec![0.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { y, compute_ns, .. } = got else { panic!() };
        assert_eq!(compute_ns, 0);
        assert_eq!(sim.virtual_clock().now(), Duration::ZERO);
        assert_eq!(y.len(), params[0].len());
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn event_beyond_timeout_window_is_not_delivered_early() {
        let mut sim = SimTransport::new(1, dims(), Duration::ZERO);
        let mut rng = Pcg32::seeded(6);
        let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 500_000_000, &mut rng);
        sim.send_to(0, msg).unwrap();
        // a 100 ms window cannot see a result due at 500 ms — exactly
        // like a real transport, the call times out (and only the
        // window elapses)
        let got = sim.recv_timeout(Duration::from_millis(100)).unwrap();
        assert!(got.is_none(), "result delivered before its time: {got:?}");
        assert_eq!(sim.virtual_clock().now(), Duration::from_millis(100));
        // a later, wide-enough window delivers it at its due time
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap();
        assert!(matches!(got, Some(LearnerMsg::Result { iter: 1, .. })), "{got:?}");
        assert_eq!(sim.virtual_clock().now(), Duration::from_millis(500));
    }

    #[test]
    fn failing_factory_backend_is_a_permanent_erasure() {
        use crate::coordinator::backend::BackendFactory;
        let d = dims();
        let factory: Arc<BackendFactory> = Arc::new(move |id| {
            if id == 0 {
                anyhow::bail!("injected: learner 0 crashed at startup");
            }
            Ok(Box::new(MockBackend::new(d, Duration::ZERO)) as Box<dyn LearnerBackend>)
        });
        let mut sim = SimTransport::from_factory(2, &factory, Duration::from_millis(1)).unwrap();
        let mut rng = Pcg32::seeded(7);
        for j in 0..2 {
            let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
            sim.send_to(j, msg).unwrap();
        }
        // only the healthy learner replies…
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { learner_id, .. } = got else { panic!() };
        assert_eq!(learner_id, 1);
        // …and the dead one never does
        let quiet = sim.recv_timeout(Duration::from_millis(50)).unwrap();
        assert!(quiet.is_none(), "dead learner produced a result: {quiet:?}");
    }

    /// A misconfigured backend (e.g. PJRT without artifacts) fails for
    /// EVERY learner — that must be a construction error, not N
    /// permanent erasures that later surface as a misleading collect
    /// timeout.
    #[test]
    fn all_backends_failing_is_a_construction_error_not_n_stragglers() {
        use crate::coordinator::backend::BackendFactory;
        let factory: Arc<BackendFactory> =
            Arc::new(|_id: u32| -> Result<Box<dyn LearnerBackend>> {
                anyhow::bail!("injected: backend cannot load")
            });
        let err = SimTransport::from_factory(3, &factory, Duration::ZERO).unwrap_err();
        assert!(
            format!("{err:#}").contains("all 3 simulated learner backends failed"),
            "{err:#}"
        );
    }

    #[test]
    fn result_buffers_recycle_through_the_shared_pool() {
        let mut sim = SimTransport::new(1, dims(), Duration::ZERO);
        let pool = sim.buf_pool().expect("sim owns a pool");
        let mut rng = Pcg32::seeded(9);
        let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { y, .. } = got else { panic!() };
        // What the controller does after decoding: return the result.
        pool.put(y);
        let hits_before = pool.stats().hits;
        let (msg2, _, _) = task(2, vec![0.0, 1.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg2).unwrap();
        assert!(
            pool.stats().hits > hits_before,
            "second task must reuse the recycled result buffer"
        );
        // A cancelled (acked) pending result returns to the pool when
        // its stale event is lazily popped.
        sim.send_to(0, CtrlMsg::Ack { iter: 2 }).unwrap();
        let resident_before = pool.stats().resident;
        assert!(sim.recv_timeout(Duration::from_millis(10)).unwrap().is_none());
        assert!(
            pool.stats().resident > resident_before,
            "cancelled result must be recycled, not dropped"
        );
    }

    /// Finite bandwidth, zero jitter: delivery time is exactly
    /// body/bw (once per broadcast) + header/bw + compute + result/bw,
    /// with the payload sizes taken from the wire-length queries.
    #[test]
    fn finite_bandwidth_charges_split_frame_transfer_exactly() {
        use crate::config::NetConfig;
        use crate::model::{ComputeModel, NetworkModel};
        let d = dims();
        let backends: Vec<Box<dyn LearnerBackend>> = (0..2)
            .map(|_| Box::new(MockBackend::new(d, Duration::ZERO)) as Box<dyn LearnerBackend>)
            .collect();
        // 1 MB/s ⇒ 1 byte costs exactly 1 µs.
        let net = NetConfig { bandwidth_mbps: 1.0, jitter: Duration::ZERO };
        let model = SystemModel {
            compute: ComputeModel::fixed(Duration::from_millis(2)),
            network: NetworkModel::from_config(&net, 0),
        };
        let mut sim = SimTransport::with_backends_and_model(backends, model);
        let mut rng = Pcg32::seeded(11);
        let (msg, params, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
        let CtrlMsg::Task { body, .. } = &msg else { unreachable!() };
        let body_us = body.wire_len() as u64; // 1 byte = 1 µs
        let header_us = task_header_wire_len(3) as u64;
        let result_us = result_wire_len(params[0].len()) as u64;
        // Same body Arc to the second learner, as the controller sends it.
        let msg2 = msg.clone();
        sim.send_to(0, msg).unwrap();
        sim.send_to(1, msg2).unwrap();
        let expect = Duration::from_micros(body_us + header_us + result_us)
            + Duration::from_millis(2); // one update
        let got = sim.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        let LearnerMsg::Result { learner_id, .. } = got else { panic!() };
        assert_eq!(learner_id, 0, "equal times pop in send order");
        assert_eq!(sim.virtual_clock().now(), expect, "exact split-frame transfer charge");
        // Learner 1 shares the SAME body transfer (charged once), so it
        // lands at the same instant, not one body-time later.
        let got = sim.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        let LearnerMsg::Result { learner_id, .. } = got else { panic!() };
        assert_eq!(learner_id, 1);
        assert_eq!(sim.virtual_clock().now(), expect);
        let stats = sim.net_stats().unwrap();
        assert_eq!(stats.bodies, 1, "shared body charged once per broadcast");
        assert_eq!(stats.tasks, 2);
        assert_eq!(stats.broadcast(), Duration::from_micros(body_us + 2 * header_us));
        assert_eq!(stats.ret(), Duration::from_micros(2 * result_us));
    }

    /// Racked topology, both learners in one rack, zero compute and
    /// jitter: the two simultaneous returns FCFS-queue over the shared
    /// uplink then the controller ingress. With both links at 1 MB/s
    /// (1 byte = 1 µs) and result frames of R bytes, the first frame
    /// arrives at t_base + 2R and the second at t_base + 3R, having
    /// queued exactly R µs behind the first on the uplink.
    #[test]
    fn racked_returns_queue_fcfs_over_uplink_and_ingress() {
        use crate::config::{NetConfig, Topology};
        use crate::model::{ComputeModel, NetworkModel};
        let d = dims();
        let backends: Vec<Box<dyn LearnerBackend>> = (0..2)
            .map(|_| Box::new(MockBackend::new(d, Duration::ZERO)) as Box<dyn LearnerBackend>)
            .collect();
        let net = NetConfig { bandwidth_mbps: 1.0, jitter: Duration::ZERO };
        let model = SystemModel {
            compute: ComputeModel::fixed(Duration::ZERO),
            network: NetworkModel::with_topology(&net, Topology::Racks { racks: 1, width: 2 }, 1.0, 0),
        };
        let mut sim = SimTransport::with_backends_and_model(backends, model);
        let tracer = Tracer::enabled(sim.clock(), 64);
        sim.set_tracer(Arc::clone(&tracer));
        let mut rng = Pcg32::seeded(41);
        let (msg, params, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
        let CtrlMsg::Task { body, .. } = &msg else { unreachable!() };
        let body_us = body.wire_len() as u64;
        let header_us = task_header_wire_len(3) as u64;
        let r_us = result_wire_len(params[0].len()) as u64;
        let msg2 = msg.clone();
        sim.send_to(0, msg).unwrap();
        sim.send_to(1, msg2).unwrap();
        // Both t_base = body + header (shared body memoized, compute 0).
        let t_base = Duration::from_micros(body_us + header_us);
        let got = sim.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        let LearnerMsg::Result { learner_id, .. } = got else { panic!() };
        assert_eq!(learner_id, 0, "equal t_base pops in send order");
        assert_eq!(
            sim.virtual_clock().now(),
            t_base + Duration::from_micros(2 * r_us),
            "first frame: uplink then ingress, no queueing"
        );
        let got = sim.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        let LearnerMsg::Result { learner_id, .. } = got else { panic!() };
        assert_eq!(learner_id, 1);
        assert_eq!(
            sim.virtual_clock().now(),
            t_base + Duration::from_micros(3 * r_us),
            "second frame queues one uplink serialization behind the first"
        );
        let stats = sim.net_stats().unwrap();
        assert_eq!(stats.ret(), Duration::from_micros(2 * r_us + 3 * r_us));
        assert_eq!(stats.queued_ns, r_us * 1_000, "second frame waited R on the uplink");
        let evs = tracer.snapshot();
        assert!(
            evs.iter().any(|e| matches!(
                e.event,
                ObsEvent::IngressQueued { iter: 1, learner: 1, queued_ns } if queued_ns == r_us * 1_000
            )),
            "{evs:?}"
        );
        assert!(
            !evs.iter().any(
                |e| matches!(e.event, ObsEvent::IngressQueued { learner: 0, .. })
            ),
            "the unqueued first frame records no ingress_queued event"
        );
    }

    /// A racked pop refused by the caller's deadline must not commit
    /// any busy state: the identical walk replays on the next call and
    /// the frame still lands at its exact analytic arrival time.
    #[test]
    fn racked_deadline_refusal_commits_no_busy_state() {
        use crate::config::{NetConfig, Topology};
        use crate::model::{ComputeModel, NetworkModel};
        let d = dims();
        let backends: Vec<Box<dyn LearnerBackend>> =
            vec![Box::new(MockBackend::new(d, Duration::ZERO))];
        // Infinite ingress (bandwidth 0 = free link), 1 MB/s uplink.
        let net = NetConfig { bandwidth_mbps: 0.0, jitter: Duration::ZERO };
        let model = SystemModel {
            compute: ComputeModel::fixed(Duration::ZERO),
            network: NetworkModel::with_topology(&net, Topology::Racks { racks: 1, width: 1 }, 1.0, 0),
        };
        let mut sim = SimTransport::with_backends_and_model(backends, model);
        let mut rng = Pcg32::seeded(42);
        let (msg, params, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
        let r_us = result_wire_len(params[0].len()) as u64;
        sim.send_to(0, msg).unwrap();
        // t_base = 0 (the infinite base link serializes the broadcast
        // in zero time); arrival = one uplink serialization = R µs,
        // which a 1 µs window cannot contain.
        assert!(sim.recv_timeout(Duration::from_micros(1)).unwrap().is_none());
        assert_eq!(sim.virtual_clock().now(), Duration::from_micros(1));
        let stats = sim.net_stats().unwrap();
        assert_eq!(stats.return_ns, 0, "refused pop records no traffic");
        assert_eq!(stats.queued_ns, 0, "refused pop commits no queueing");
        // The replayed walk delivers at the same absolute arrival.
        assert!(sim.recv_timeout(Duration::from_secs(10)).unwrap().is_some());
        assert_eq!(sim.virtual_clock().now(), Duration::from_micros(r_us));
        assert_eq!(sim.net_stats().unwrap().ret(), Duration::from_micros(r_us));
    }

    /// Racked topologies charge Ack frames as traffic (accounting
    /// only): the cancellation is still synchronous, the cancelled
    /// result still never counts as return traffic, and the flat
    /// default (covered by the tests above) keeps acks free.
    #[test]
    fn racked_ack_is_charged_without_delaying_cancellation() {
        use crate::config::{NetConfig, Topology};
        use crate::model::{ComputeModel, NetworkModel};
        use crate::transport::msg::ack_wire_len;
        let d = dims();
        let backends: Vec<Box<dyn LearnerBackend>> =
            vec![Box::new(MockBackend::new(d, Duration::ZERO))];
        let net = NetConfig { bandwidth_mbps: 1.0, jitter: Duration::ZERO };
        let model = SystemModel {
            compute: ComputeModel::fixed(Duration::from_millis(2)),
            network: NetworkModel::with_topology(&net, Topology::Racks { racks: 1, width: 1 }, 1.0, 0),
        };
        let mut sim = SimTransport::with_backends_and_model(backends, model);
        let mut rng = Pcg32::seeded(43);
        let (msg, _, _) = task(7, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        sim.send_to(0, CtrlMsg::Ack { iter: 7 }).unwrap();
        assert!(sim.recv_timeout(Duration::from_secs(1)).unwrap().is_none());
        let stats = sim.net_stats().unwrap();
        assert_eq!(stats.acks, 1);
        assert_eq!(stats.ack_ns, ack_wire_len() as u64 * 1_000, "9 bytes at 1 MB/s");
        assert_eq!(stats.return_ns, 0, "cancelled result is still not return traffic");
        assert_eq!(stats.queued_ns, 0, "cancelled result never touched the links");
    }

    /// A cancelled (acked) result was never sent by the real learner:
    /// its return leg must not count as traffic, while the broadcast
    /// leg (which the controller really did send) must.
    #[test]
    fn cancelled_result_return_leg_is_not_recorded() {
        use crate::config::NetConfig;
        use crate::model::{ComputeModel, NetworkModel};
        let d = dims();
        let backends: Vec<Box<dyn LearnerBackend>> =
            vec![Box::new(MockBackend::new(d, Duration::ZERO))];
        let net = NetConfig { bandwidth_mbps: 1.0, jitter: Duration::ZERO };
        let model = SystemModel {
            compute: ComputeModel::fixed(Duration::from_millis(2)),
            network: NetworkModel::from_config(&net, 0),
        };
        let mut sim = SimTransport::with_backends_and_model(backends, model);
        let mut rng = Pcg32::seeded(13);
        let (msg, _, _) = task(4, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        sim.send_to(0, CtrlMsg::Ack { iter: 4 }).unwrap();
        assert!(sim.recv_timeout(Duration::from_secs(1)).unwrap().is_none());
        let stats = sim.net_stats().unwrap();
        assert!(stats.broadcast_ns > 0, "the broadcast really was sent");
        assert_eq!(stats.return_ns, 0, "a cancelled result is not return traffic");
    }

    /// The default model is a free network: nothing is charged, stats
    /// stay zero — the bit-identity guarantee of the refactor.
    #[test]
    fn free_network_charges_nothing() {
        let mut sim = SimTransport::new(1, dims(), Duration::from_millis(2));
        let mut rng = Pcg32::seeded(12);
        let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(sim.virtual_clock().now(), Duration::from_millis(2));
        assert_eq!(sim.net_stats().unwrap(), NetStats::default());
    }

    /// Cancelled in-flight results are wasted work: the transport
    /// counts their exact wire bytes + modeled compute always, and —
    /// with a tracer installed — records a `result_cancelled` event on
    /// the shared timeline (delivered results record `frame_recv`).
    #[test]
    fn cancellation_waste_is_counted_and_traced() {
        let mut sim = SimTransport::new(1, dims(), Duration::from_millis(2));
        assert_eq!(sim.waste_stats(), Some(WasteStats::default()));
        let tracer = Tracer::enabled(sim.clock(), 64);
        sim.set_tracer(Arc::clone(&tracer));
        let mut rng = Pcg32::seeded(21);
        let (msg, params, _) = task(3, vec![1.0, 0.0, 0.0], 50_000_000, &mut rng);
        sim.send_to(0, msg).unwrap();
        sim.send_to(0, CtrlMsg::Ack { iter: 3 }).unwrap();
        assert!(sim.recv_timeout(Duration::from_millis(100)).unwrap().is_none());
        let waste = sim.waste_stats().unwrap();
        assert_eq!(waste.results, 1);
        assert_eq!(waste.bytes, result_wire_len(params[0].len()) as u64);
        assert_eq!(waste.compute_ns, 2_000_000, "one modeled update was burned");
        let evs = tracer.snapshot();
        assert!(
            evs.iter().any(|e| matches!(
                e.event,
                ObsEvent::ResultCancelled { iter: 3, learner: 0, .. }
            )),
            "{evs:?}"
        );
        // a delivered result records a frame receipt instead
        let (msg2, _, _) = task(4, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg2).unwrap();
        assert!(sim.recv_timeout(Duration::from_secs(1)).unwrap().is_some());
        assert!(sim
            .tracer
            .snapshot()
            .iter()
            .any(|e| matches!(e.event, ObsEvent::FrameRecv { learner: 0, .. })));
        assert_eq!(sim.waste_stats().unwrap().results, 1, "delivery is not waste");
    }

    #[test]
    fn injected_crash_swallows_task_and_is_reported_lost() {
        let mut sim = SimTransport::new(2, dims(), Duration::from_millis(1));
        let mut rng = Pcg32::seeded(30);
        // Permanent crash on learner 0, injected before the broadcast
        // (the controller's order: draw plan, inject, then send).
        let plan = FaultPlan { crashes: vec![(0, None)], ..FaultPlan::default() };
        sim.inject_faults(1, &plan);
        for j in 0..2 {
            let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
            sim.send_to(j, msg).unwrap();
        }
        assert_eq!(sim.lost_for_iter(1), Some(&[0usize][..]));
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { learner_id, .. } = got else { panic!() };
        assert_eq!(learner_id, 1, "only the survivor replies");
        assert!(sim.recv_timeout(Duration::from_millis(10)).unwrap().is_none());
        // Permanent: still down next iteration.
        let (msg, _, _) = task(2, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        assert_eq!(sim.lost_for_iter(2), Some(&[0usize][..]));
        assert_eq!(sim.lost_for_iter(1), None, "stale iteration is forgotten");
    }

    #[test]
    fn crash_restart_brings_the_learner_back_after_downtime() {
        let mut sim = SimTransport::new(1, dims(), Duration::from_millis(1));
        let mut rng = Pcg32::seeded(31);
        // Down for 50 virtual ms from t=0.
        sim.inject_faults(1, &FaultPlan {
            crashes: vec![(0, Some(50_000_000))],
            ..FaultPlan::default()
        });
        let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        assert_eq!(sim.lost_for_iter(1), Some(&[0usize][..]));
        assert!(sim.recv_timeout(Duration::from_millis(100)).unwrap().is_none());
        // Clock is now at 100 ms > 50 ms: the learner has restarted.
        let (msg, _, _) = task(2, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        assert_eq!(sim.lost_for_iter(2), None);
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        assert!(matches!(got, LearnerMsg::Result { iter: 2, .. }));
    }

    #[test]
    fn omitted_result_is_computed_charged_and_dropped() {
        use crate::config::NetConfig;
        use crate::model::{ComputeModel, NetworkModel};
        let d = dims();
        let backends: Vec<Box<dyn LearnerBackend>> =
            vec![Box::new(MockBackend::new(d, Duration::ZERO))];
        let net = NetConfig { bandwidth_mbps: 1.0, jitter: Duration::ZERO };
        let model = SystemModel {
            compute: ComputeModel::fixed(Duration::from_millis(2)),
            network: NetworkModel::from_config(&net, 0),
        };
        let mut sim = SimTransport::with_backends_and_model(backends, model);
        let mut rng = Pcg32::seeded(32);
        sim.inject_faults(1, &FaultPlan { omissions: vec![0], ..FaultPlan::default() });
        let (msg, params, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        // Lost is known at scheduling time — before any recv.
        assert_eq!(sim.lost_for_iter(1), Some(&[0usize][..]));
        assert!(sim.recv_timeout(Duration::from_secs(1)).unwrap().is_none());
        // The learner really computed and transmitted: compute is
        // wasted and the return frame counts as traffic.
        let waste = sim.waste_stats().unwrap();
        assert_eq!(waste.results, 1);
        assert_eq!(waste.compute_ns, 2_000_000);
        let result_us = result_wire_len(params[0].len()) as u64;
        assert_eq!(sim.net_stats().unwrap().ret(), Duration::from_micros(result_us));
        // Omission is per-iteration: the next round delivers.
        let (msg, _, _) = task(2, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        assert!(sim.recv_timeout(Duration::from_secs(1)).unwrap().is_some());
    }

    #[test]
    fn crash_cancels_in_flight_result_and_traces() {
        let mut sim = SimTransport::new(1, dims(), Duration::from_millis(1));
        let tracer = Tracer::enabled(sim.clock(), 64);
        sim.set_tracer(Arc::clone(&tracer));
        let mut rng = Pcg32::seeded(33);
        // Task in flight (50 ms delay), then the learner crashes.
        let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 50_000_000, &mut rng);
        sim.send_to(0, msg).unwrap();
        sim.inject_faults(2, &FaultPlan { crashes: vec![(0, None)], ..FaultPlan::default() });
        assert!(sim.recv_timeout(Duration::from_millis(200)).unwrap().is_none());
        assert_eq!(sim.waste_stats().unwrap().results, 1, "in-flight result died with the crash");
        let evs = tracer.snapshot();
        assert!(
            evs.iter().any(|e| matches!(
                e.event,
                ObsEvent::CrashInjected { iter: 2, learner: 0, down_ns: None }
            )),
            "{evs:?}"
        );
        // A second crash directive against a down learner is moot.
        sim.inject_faults(3, &FaultPlan { crashes: vec![(0, Some(1))], ..FaultPlan::default() });
        let crashes = tracer
            .snapshot()
            .iter()
            .filter(|e| matches!(e.event, ObsEvent::CrashInjected { .. }))
            .count();
        assert_eq!(crashes, 1, "already-down learners are not re-crashed");
    }

    /// A corrupted result still ARRIVES — perturbed, traced, and NOT
    /// reported lost (only the verified decoder can tell it's bad) —
    /// and the corruption is scoped to its iteration.
    #[test]
    fn corrupted_result_is_delivered_perturbed_not_lost() {
        use crate::config::CorruptMode;
        let mut sim = SimTransport::new(1, dims(), Duration::from_millis(1));
        let tracer = Tracer::enabled(sim.clock(), 64);
        sim.set_tracer(Arc::clone(&tracer));
        let mut rng = Pcg32::seeded(35);
        // Reference: the clean result for the same task stream.
        let mut clean_sim = SimTransport::new(1, dims(), Duration::from_millis(1));
        let mut clean_rng = Pcg32::seeded(35);
        sim.inject_faults(1, &FaultPlan {
            corruptions: vec![CorruptionDirective {
                learner: 0,
                mode: CorruptMode::Adversarial,
                draw: 42,
            }],
            ..FaultPlan::default()
        });
        let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        let (clean_msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut clean_rng);
        clean_sim.send_to(0, clean_msg).unwrap();
        // Not lost: the controller must wait for (and receive) it.
        assert_eq!(sim.lost_for_iter(1), None);
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { iter, y, .. } = got else { panic!() };
        assert_eq!(iter, 1);
        let clean = clean_sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { y: clean_y, .. } = clean else { panic!() };
        assert_ne!(y, clean_y, "the delivered result must be perturbed");
        assert!(y.iter().all(|&v| v.abs() >= 1.0e3), "adversarial overwrite");
        assert!(tracer.snapshot().iter().any(|e| matches!(
            e.event,
            ObsEvent::CorruptionInjected { iter: 1, learner: 0, mode: "adversarial" }
        )));
        // Per-iteration scope: the next round is clean again.
        let (msg, _, _) = task(2, vec![1.0, 0.0, 0.0], 0, &mut rng);
        sim.send_to(0, msg).unwrap();
        let got = sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { y, .. } = got else { panic!() };
        let (clean_msg, _, _) = task(2, vec![1.0, 0.0, 0.0], 0, &mut clean_rng);
        clean_sim.send_to(0, clean_msg).unwrap();
        let clean = clean_sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap();
        let LearnerMsg::Result { y: clean_y, .. } = clean else { panic!() };
        assert_eq!(y, clean_y, "corruption must not leak into later iterations");
    }

    #[test]
    fn dead_backend_is_reported_lost_for_fail_fast() {
        use crate::coordinator::backend::BackendFactory;
        let d = dims();
        let factory: Arc<BackendFactory> = Arc::new(move |id| {
            if id == 0 {
                anyhow::bail!("injected: learner 0 dead at startup");
            }
            Ok(Box::new(MockBackend::new(d, Duration::ZERO)) as Box<dyn LearnerBackend>)
        });
        let mut sim = SimTransport::from_factory(2, &factory, Duration::from_millis(1)).unwrap();
        let mut rng = Pcg32::seeded(34);
        for j in 0..2 {
            let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
            sim.send_to(j, msg).unwrap();
        }
        assert_eq!(sim.lost_for_iter(1), Some(&[0usize][..]));
    }

    #[test]
    fn equal_times_pop_in_send_order() {
        let mut sim = SimTransport::new(3, dims(), Duration::from_millis(5));
        let mut rng = Pcg32::seeded(5);
        for j in [2usize, 0, 1] {
            let (msg, _, _) = task(1, vec![1.0, 0.0, 0.0], 0, &mut rng);
            sim.send_to(j, msg).unwrap();
        }
        let mut order = Vec::new();
        for _ in 0..3 {
            let LearnerMsg::Result { learner_id, .. } =
                sim.recv_timeout(Duration::from_secs(1)).unwrap().unwrap()
            else {
                panic!()
            };
            order.push(learner_id);
        }
        assert_eq!(order, vec![2, 0, 1], "ties must break by send order");
    }
}
