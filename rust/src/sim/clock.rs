//! Time virtualization: a [`Clock`] trait with a wall-clock
//! implementation ([`RealClock`]) and a discrete-event counter
//! ([`VirtualClock`]).
//!
//! Everything in the coordination layer that reads or spends time —
//! [`crate::metrics::Timer`], the controller's collect deadline, the
//! learner's straggler wait, the mock backend's emulated compute —
//! goes through a [`ClockRef`] instead of touching
//! `std::time::Instant` / `std::thread::sleep` directly. Under
//! [`RealClock`] the behaviour is exactly the pre-sim behaviour; under
//! [`VirtualClock`] a "sleep" is an instantaneous jump of the virtual
//! counter, which is what lets straggler sweeps with multi-second
//! injected delays run at hardware speed (see [`super::transport`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// A monotonic time source plus the ability to spend time on it.
///
/// `now()` is relative to the clock's own epoch — only differences and
/// ordering are meaningful, which is all the coordination layer ever
/// uses (timers, deadlines, delays).
pub trait Clock: Send + Sync + std::fmt::Debug {
    /// Time elapsed since the clock's epoch.
    fn now(&self) -> Duration;

    /// Spend `d` of this clock's time (really for [`RealClock`],
    /// instantaneously for [`VirtualClock`]).
    fn sleep(&self, d: Duration);
}

/// Shared handle to a clock; cheap to clone, safe to hand to threads.
pub type ClockRef = Arc<dyn Clock>;

/// Wall-clock time: `now` is `Instant`-based, `sleep` really sleeps.
#[derive(Debug)]
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    pub fn new() -> RealClock {
        RealClock { epoch: Instant::now() }
    }
}

impl Default for RealClock {
    fn default() -> RealClock {
        RealClock::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }

    fn sleep(&self, d: Duration) {
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }
}

/// The process-wide shared real clock (a single epoch, so durations
/// from different components are comparable).
pub fn real_clock() -> ClockRef {
    static REAL: OnceLock<ClockRef> = OnceLock::new();
    REAL.get_or_init(|| Arc::new(RealClock::new())).clone()
}

/// Discrete-event virtual time: a nanosecond counter that only moves
/// when someone spends time on it. Deterministic — two runs that issue
/// the same advances read the same timestamps, bit for bit.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now_ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Fresh shared virtual clock starting at t = 0.
    pub fn shared() -> Arc<VirtualClock> {
        Arc::new(VirtualClock::new())
    }

    pub fn now_ns(&self) -> u64 {
        self.now_ns.load(Ordering::SeqCst)
    }

    /// Move time forward by `d`.
    pub fn advance(&self, d: Duration) {
        self.now_ns.fetch_add(duration_ns(d), Ordering::SeqCst);
    }

    /// Move time forward **to** `t` (no-op if `t` is in the past —
    /// virtual time, like real time, never runs backwards).
    pub fn advance_to(&self, t: Duration) {
        self.now_ns.fetch_max(duration_ns(t), Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.now_ns())
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

fn duration_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_is_monotonic_and_sleeps() {
        let c = RealClock::new();
        let a = c.now();
        c.sleep(Duration::from_millis(5));
        let b = c.now();
        assert!(b >= a + Duration::from_millis(5));
    }

    #[test]
    fn virtual_clock_advances_without_wall_time() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        let wall = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert_eq!(c.now(), Duration::from_secs(3600));
        assert!(wall.elapsed() < Duration::from_secs(1), "virtual sleep must be instant");
    }

    #[test]
    fn advance_to_is_monotone() {
        let c = VirtualClock::new();
        c.advance_to(Duration::from_millis(50));
        assert_eq!(c.now(), Duration::from_millis(50));
        c.advance_to(Duration::from_millis(20)); // in the past: no-op
        assert_eq!(c.now(), Duration::from_millis(50));
        c.advance_to(Duration::from_millis(80));
        assert_eq!(c.now(), Duration::from_millis(80));
    }

    #[test]
    fn shared_real_clock_is_one_epoch() {
        let a = real_clock();
        let b = real_clock();
        let t1 = a.now();
        let t2 = b.now();
        assert!(t2 >= t1);
        assert!(t2 - t1 < Duration::from_secs(1));
    }
}
