//! Straggler-sweep runner shared by the `coded-marl sim-sweep`
//! subcommand, `examples/straggler_sweep.rs`, and the ablation bench:
//! one short training run per (scheme, straggler count) cell, mean
//! per-iteration time over the non-warmup iterations.
//!
//! The runner is time-mode agnostic — it builds pools through
//! [`crate::coordinator::spawn_pool`], so `base.time_mode` decides
//! whether a cell costs real wall-clock (threads + sleeps) or virtual
//! nanoseconds (discrete events). Under `TimeMode::Virtual` a full
//! 5-scheme × 5-k grid with the paper's t_s = 250 ms finishes in well
//! under a second.
//!
//! ## Sharded execution
//!
//! Cells are independent short trainings (fresh pool, fresh controller,
//! fresh virtual clock), so in virtual time the grid runs on a
//! `std::thread` shard pool (`TrainConfig::sweep_threads`; 0 = one per
//! core). Each scheme's seed is **derived** from the base seed with
//! [`derive_scheme_seed`] — a pure function, so serial and parallel
//! runs at any thread count produce bit-identical cells, and all k
//! cells of one scheme share one assignment matrix (the paper's sweeps
//! vary k against a *fixed* code, and it is what lets the per-scheme
//! analytics be computed once instead of per cell). Results are written
//! into pre-assigned slots, so cell order never depends on scheduling.
//! Real-time sweeps always run serially: wall-clock cells must not
//! contend for cores.

use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coding::decoder::PlanCacheStats;
use crate::coding::{Code, CodeParams, Scheme};
use crate::config::{Backend, DelayDist, TimeMode, Topology, TrainConfig};
use crate::coordinator::{
    backend_factory, spawn_pool, ByzantineStats, Controller, FaultError, FaultStats, RunSpec,
};
use crate::metrics::table::Table;
use crate::metrics::{RunLog, Stats};
use crate::model::NetStats;
use crate::obs::{AttrSummary, Quantiles, WasteStats};

/// A sweep grid: the cross product of `schemes` × `ks`, run on top of
/// `base` (whose `scheme`/`straggler.k`/`straggler.delay` are
/// overwritten per cell).
pub struct SweepConfig {
    pub base: TrainConfig,
    pub spec: RunSpec,
    pub schemes: Vec<Scheme>,
    pub ks: Vec<usize>,
    /// Injected delay t_s applied to every cell with k > 0.
    pub delay: Duration,
    /// AOT artifacts directory, used only when `base.backend` is PJRT
    /// (mock sweeps never read it).
    pub artifacts_dir: std::path::PathBuf,
}

/// The baseline sweep cell config shared by the `sim-sweep` subcommand
/// and `examples/straggler_sweep.rs`: mock backend in virtual time,
/// one 25-step episode per iteration, and one warmup iteration on top
/// of `iterations` measured ones. Callers tweak the returned config
/// (e.g. `time_mode = Real` for a wall-clock reference run).
pub fn sweep_base(
    preset: impl Into<String>,
    n_learners: usize,
    iterations: usize,
    mock_compute: Duration,
    seed: u64,
) -> TrainConfig {
    let mut cfg = TrainConfig::new(preset);
    cfg.backend = Backend::Mock;
    cfg.time_mode = TimeMode::Virtual;
    cfg.n_learners = n_learners;
    cfg.iterations = iterations + 1; // +1 warmup
    cfg.episodes_per_iter = 1;
    cfg.episode_len = 25;
    cfg.warmup_iters = 1;
    cfg.mock_compute = mock_compute;
    cfg.seed = seed;
    cfg
}

/// Total simulated training time across cells — the "how much time did
/// the sweep model" headline. Sums the **exact** per-cell totals: the
/// old `mean_iter × measured_iters` form re-multiplied an already
/// floor-divided mean (losing up to `iters − 1` ns per cell) and the
/// `Duration × u32` panicked on overflow at large virtual-time grids.
pub fn simulated_total(cells: &[SweepCell]) -> Duration {
    cells.iter().map(|c| c.total).sum()
}

/// One (scheme, k) cell's outcome.
pub struct SweepCell {
    pub scheme: Scheme,
    pub k: usize,
    /// Exact summed training time over the non-warmup iterations — the
    /// value downstream aggregation must consume (means are display
    /// derivatives; re-multiplying them re-truncates).
    pub total: Duration,
    /// Exact summed collect/wait time over the non-warmup iterations.
    pub wait: Duration,
    /// Mean per-iteration training time over non-warmup iterations —
    /// the y-axis of the paper's Figs. 4-5. Derived: `total / iters`.
    pub mean_iter: Duration,
    /// Mean of the collect/wait phase alone. Derived: `wait / iters`.
    pub mean_wait: Duration,
    /// Iterations averaged over (excludes warmup).
    pub measured_iters: usize,
    /// The scheme's compute redundancy (total agent-updates / M).
    pub redundancy: f64,
    /// Worst-case straggler tolerance of the assignment matrix.
    pub tolerance: usize,
    /// Decode-plan cache counters from the cell's controller: one miss
    /// per *distinct* erasure pattern, hits for every repeat.
    pub decode_plan: PlanCacheStats,
    /// Network-model transfer telemetry (zero under the default free
    /// model). The totals cover exactly the broadcasting (non-warmup)
    /// iterations, so `net.broadcast() / measured_iters` is the
    /// per-iteration broadcast transfer.
    pub net: NetStats,
    /// Per-iteration training-time statistics over the non-warmup
    /// iterations (seconds) — mergeable across cells via
    /// [`Stats::merge`] for grid-level summaries
    /// ([`grid_iter_stats`]).
    pub iter_stats: Stats,
    /// Streaming per-iteration time quantiles (seconds) over the same
    /// non-warmup iterations — P² sketches, so p50/p90/p99 come at
    /// O(1) memory per cell. **Not** mergeable across cells (unlike
    /// [`Stats`]); grid summaries report the per-cell range instead
    /// ([`grid_p99_range`]).
    pub iter_q: Quantiles,
    /// Wasted work over the cell: post-decodable / duplicate /
    /// malformed arrivals plus transport-cancelled in-flight results.
    pub waste: WasteStats,
    /// Straggler-attribution summary (decodability front, tail
    /// learner, injected-vs-organic split).
    pub attr: AttrSummary,
    /// Wall-clock spent executing the cell (not simulated time).
    pub wall: Duration,
}

/// Range of the per-cell p99 iteration times across the grid, seconds
/// (`(min, max)`; `None` when no cell measured anything). P² sketches
/// cannot be merged, so the grid-level tail is reported as a range
/// over cells rather than a pooled quantile.
pub fn grid_p99_range(cells: &[SweepCell]) -> Option<(f64, f64)> {
    let mut range: Option<(f64, f64)> = None;
    for c in cells {
        if c.iter_q.count() == 0 {
            continue;
        }
        let p99 = c.iter_q.p99();
        if !p99.is_finite() {
            continue;
        }
        range = Some(match range {
            None => (p99, p99),
            Some((lo, hi)) => (lo.min(p99), hi.max(p99)),
        });
    }
    range
}

/// Grid-level per-iteration statistics: every cell's [`Stats`] merged
/// with the parallel-Welford [`Stats::merge`] — identical to pushing
/// all iterations into one accumulator, without re-walking the logs.
pub fn grid_iter_stats(cells: &[SweepCell]) -> Stats {
    let mut all = Stats::new();
    for c in cells {
        all.merge(&c.iter_stats);
    }
    all
}

/// Per-scheme seed derived from the experiment seed (splitmix64
/// finalizer): schemes train on decorrelated streams, while all k
/// cells of one scheme share a seed — and therefore one assignment
/// matrix — so redundancy/tolerance are computed once per scheme and
/// k comparisons run against a fixed code. Derived from the scheme's
/// stable identity (its position in [`Scheme::ALL`]), NOT its position
/// in the sweep's `--schemes` list, so `(seed, scheme)` names the same
/// cell no matter which other schemes are swept alongside it.
pub fn derive_scheme_seed(base: u64, scheme: Scheme) -> u64 {
    let id = Scheme::ALL
        .iter()
        .position(|&s| s == scheme)
        .expect("scheme listed in Scheme::ALL") as u64;
    let mut z = base ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(id + 1);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Exact non-warmup timing sums of a run log. Means are derived on
/// demand (see [`NonWarmup::mean_total`]) so downstream aggregation —
/// [`simulated_total`], the sweep JSON — can always consume the exact
/// sums and never re-multiply a floor-divided mean back up.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NonWarmup {
    /// Exact summed per-iteration training time.
    pub total: Duration,
    /// Exact summed collect/wait time.
    pub wait: Duration,
    /// Iterations summed over (excludes warmup).
    pub iters: usize,
}

impl NonWarmup {
    /// Mean per-iteration training time (zero when nothing measured).
    pub fn mean_total(&self) -> Duration {
        if self.iters == 0 {
            Duration::ZERO
        } else {
            self.total / self.iters as u32
        }
    }

    /// Mean per-iteration collect/wait time.
    pub fn mean_wait(&self) -> Duration {
        if self.iters == 0 {
            Duration::ZERO
        } else {
            self.wait / self.iters as u32
        }
    }
}

/// Exact (total, wait) sums over the non-warmup iterations of a run
/// log, with the means available as derived accessors.
pub fn mean_non_warmup(log: &RunLog) -> NonWarmup {
    let mut total = Duration::ZERO;
    let mut wait = Duration::ZERO;
    let mut iters = 0usize;
    for r in log.records.iter().filter(|r| r.decode_method != "warmup") {
        total += r.timing.total;
        wait += r.timing.wait;
        iters += 1;
    }
    NonWarmup { total, wait, iters }
}

/// Analytics shared by every k cell of one scheme, computed once.
struct SchemeInfo {
    seed: u64,
    redundancy: f64,
    tolerance: usize,
}

/// Run one (scheme, k) cell: a fresh short training with the scheme's
/// derived seed. Pure function of its arguments — the shard pool and
/// the serial loop produce identical cells. Only the grid's `first`
/// cell honours `base.trace_out` (every cell tracing would have N
/// cells overwrite one file); tracing never perturbs timing, so the
/// traced cell is bit-identical to its untraced twin.
fn run_cell(
    sweep: &SweepConfig,
    scheme: Scheme,
    k: usize,
    info: &SchemeInfo,
    first: bool,
) -> Result<SweepCell> {
    let wall_t = std::time::Instant::now();
    let mut cfg = sweep.base.clone();
    cfg.scheme = scheme;
    if !first {
        cfg.trace_out = None;
    }
    // A trace-replay sweep's disturbance comes from the recorded
    // trace, not the synthetic injector (the combination is rejected
    // by `TrainConfig::validate`); such sweeps run with `ks = [0]`.
    if cfg.trace.is_none() {
        cfg.straggler.k = k;
        cfg.straggler.delay = sweep.delay;
    }
    cfg.seed = info.seed;
    let factory = backend_factory(&cfg, sweep.artifacts_dir.clone(), &sweep.spec);
    let pool = spawn_pool(&cfg, factory)?;
    let mut ctrl = Controller::new(cfg, sweep.spec.clone(), pool)
        .with_context(|| format!("building controller for {scheme} k={k}"))?;
    ctrl.train().with_context(|| format!("training cell {scheme} k={k}"))?;
    let nw = mean_non_warmup(&ctrl.log);
    let decode_plan = ctrl.decode_plan_stats();
    let net = ctrl.net_stats().unwrap_or_default();
    let mut iter_stats = Stats::new();
    let mut iter_q = Quantiles::new();
    for r in ctrl.log.records.iter().filter(|r| r.decode_method != "warmup") {
        iter_stats.push(r.timing.total.as_secs_f64());
        iter_q.push(r.timing.total.as_secs_f64());
    }
    let waste = ctrl.waste_stats();
    let attr = ctrl.attribution().summary();
    ctrl.shutdown();
    Ok(SweepCell {
        scheme,
        k,
        total: nw.total,
        wait: nw.wait,
        mean_iter: nw.mean_total(),
        mean_wait: nw.mean_wait(),
        measured_iters: nw.iters,
        redundancy: info.redundancy,
        tolerance: info.tolerance,
        decode_plan,
        net,
        iter_stats,
        iter_q,
        waste,
        attr,
        wall: wall_t.elapsed(),
    })
}

/// Shard-pool width for this sweep: `base.sweep_threads` (0 = one per
/// available core), capped by the cell count. Real-time sweeps are
/// always serial — their cells measure wall-clock and must not contend
/// for cores.
fn shard_width(sweep: &SweepConfig, jobs: usize) -> usize {
    if sweep.base.time_mode == TimeMode::Real {
        return 1;
    }
    let requested = match sweep.base.sweep_threads {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        t => t,
    };
    requested.clamp(1, jobs.max(1))
}

/// Run the grid; cells are independent short trainings (fresh pool,
/// fresh controller, fresh virtual clock), sharded across
/// `base.sweep_threads` worker threads in virtual time (see module
/// docs). Cell order and content are identical at any thread count.
pub fn run_sweep(sweep: &SweepConfig) -> Result<Vec<SweepCell>> {
    // Per-scheme analytics, hoisted out of the cell loop: redundancy
    // and tolerance depend only on (scheme, N, M, p_m, scheme seed) —
    // previously recomputed per cell, with the brute-force tolerance
    // dominating the whole sweep beyond paper scale.
    let infos: Vec<SchemeInfo> = sweep
        .schemes
        .iter()
        .map(|&scheme| {
            let seed = derive_scheme_seed(sweep.base.seed, scheme);
            let code = Code::build(&CodeParams {
                scheme,
                n: sweep.base.n_learners,
                m: sweep.spec.m,
                p_m: sweep.base.p_m,
                seed,
            });
            SchemeInfo { seed, redundancy: code.redundancy(), tolerance: code.worst_case_tolerance() }
        })
        .collect();
    let jobs: Vec<(usize, usize)> = (0..sweep.schemes.len())
        .flat_map(|s| sweep.ks.iter().map(move |&k| (s, k)))
        .collect();
    let threads = shard_width(sweep, jobs.len());
    if threads <= 1 {
        return jobs
            .iter()
            .enumerate()
            .map(|(i, &(s, k))| run_cell(sweep, sweep.schemes[s], k, &infos[s], i == 0))
            .collect();
    }
    // Shard pool: a shared job cursor and one pre-assigned result slot
    // per cell, so output order is position-determined, never
    // scheduling-determined.
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<SweepCell>>>> =
        jobs.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                let Some(&(s, k)) = jobs.get(i) else { break };
                let out = run_cell(sweep, sweep.schemes[s], k, &infos[s], i == 0);
                *slots[i].lock().expect("sweep slot poisoned") = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("sweep slot poisoned")
                .expect("scope joined every worker")
        })
        .collect()
}

/// Render the sweep as the schemes × k table the examples print
/// (cells in ms, plus the scheme's redundancy and tolerance). Cells
/// are indexed by a `(scheme, k)` map built once — the old linear
/// `find` made rendering O(cells²) and silently let a later duplicate
/// cell overwrite the scheme info.
pub fn render_table(cells: &[SweepCell], ks: &[usize]) -> String {
    let mut headers: Vec<String> = vec!["scheme".into()];
    headers.extend(ks.iter().map(|k| format!("k={k}")));
    headers.push("redundancy".into());
    headers.push("tolerance".into());
    headers.push("iter p50/p99".into());
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    let mut index: std::collections::HashMap<(Scheme, usize), &SweepCell> =
        std::collections::HashMap::with_capacity(cells.len());
    let mut schemes: Vec<Scheme> = Vec::new();
    for c in cells {
        index.entry((c.scheme, c.k)).or_insert(c);
        if !schemes.contains(&c.scheme) {
            schemes.push(c.scheme);
        }
    }
    for scheme in schemes {
        let mut row = vec![scheme.name().to_string()];
        let mut info: Option<(f64, usize)> = None;
        // The scheme's tail summary: the worst-p99 cell across its
        // swept ks (P² sketches are per-cell; they cannot be pooled).
        let mut tail: Option<(f64, f64)> = None;
        for &k in ks {
            match index.get(&(scheme, k)) {
                Some(c) => {
                    row.push(format!("{:.1}ms", c.mean_iter.as_secs_f64() * 1e3));
                    if info.is_none() {
                        info = Some((c.redundancy, c.tolerance));
                    }
                    if c.iter_q.count() > 0 && c.iter_q.p99().is_finite() {
                        let (p50, p99) = (c.iter_q.p50(), c.iter_q.p99());
                        if tail.map_or(true, |(_, hi)| p99 > hi) {
                            tail = Some((p50, p99));
                        }
                    }
                }
                None => row.push("-".into()),
            }
        }
        let (red, tol) = info.unwrap_or((f64::NAN, 0));
        row.push(format!("{red:.1}x"));
        row.push(tol.to_string());
        row.push(match tail {
            Some((p50, p99)) => format!("{:.1}/{:.1}ms", p50 * 1e3, p99 * 1e3),
            None => "-".into(),
        });
        table.row(&row);
    }
    table.render()
}

/// Quantile/attribution values for serialization: an empty sketch
/// reports NaN, which neither CSV consumers nor strict JSON parsers
/// accept — write 0 instead (a cell that measured nothing).
fn finite_or_zero(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

/// One CSV row per cell (`scheme,k,mean_iter_s,mean_wait_s,total_s,…`;
/// `total_s`/`wait_s` are the exact sums, the means are display-only).
pub fn write_csv(cells: &[SweepCell], path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(
        f,
        "scheme,k,mean_iter_s,mean_wait_s,total_s,wait_s,iters,redundancy,tolerance,\
         decode_plan_hits,decode_plan_misses,net_broadcast_s,net_return_s,\
         iter_p50_s,iter_p90_s,iter_p99_s,wasted_results,wasted_bytes,wasted_compute_s,\
         front_p50_s,front_p99_s,tail_learner,tail_p99_s,injected_share"
    )?;
    for c in cells {
        writeln!(
            f,
            "{},{},{:.6},{:.6},{:.9},{:.9},{},{:.3},{},{},{},{:.9},{:.9},\
             {:.9},{:.9},{:.9},{},{},{:.9},{:.9},{:.9},{},{:.9},{:.6}",
            c.scheme.name(),
            c.k,
            c.mean_iter.as_secs_f64(),
            c.mean_wait.as_secs_f64(),
            c.total.as_secs_f64(),
            c.wait.as_secs_f64(),
            c.measured_iters,
            c.redundancy,
            c.tolerance,
            c.decode_plan.hits,
            c.decode_plan.misses,
            c.net.broadcast().as_secs_f64(),
            c.net.ret().as_secs_f64(),
            finite_or_zero(c.iter_q.p50()),
            finite_or_zero(c.iter_q.p90()),
            finite_or_zero(c.iter_q.p99()),
            c.waste.results,
            c.waste.bytes,
            c.waste.compute_secs(),
            finite_or_zero(c.attr.front_p50_s),
            finite_or_zero(c.attr.front_p99_s),
            c.attr.tail_learner.map_or(-1i64, |j| j as i64),
            finite_or_zero(c.attr.tail_p99_s),
            finite_or_zero(c.attr.injected_share),
        )?;
    }
    f.flush()
}

/// One cell as a JSON object (shared by `BENCH_sweep.json` and
/// `BENCH_scale.json`; plain enum names and finite numbers only, so no
/// string escaping is needed).
fn cell_json(c: &SweepCell) -> String {
    // Per-iteration network legs: the totals cover exactly the
    // broadcasting (non-warmup) iterations.
    let per_iter = |total: Duration| -> f64 {
        if c.measured_iters == 0 {
            0.0
        } else {
            total.as_secs_f64() / c.measured_iters as f64
        }
    };
    format!(
        "{{\"scheme\": \"{}\", \"k\": {}, \"mean_iter_s\": {:.9}, \
         \"mean_wait_s\": {:.9}, \"total_s\": {:.9}, \"wait_s\": {:.9}, \"iters\": {}, \
         \"redundancy\": {:.6}, \"tolerance\": {}, \"decode_plan_hits\": {}, \
         \"decode_plan_misses\": {}, \"net_broadcast_s\": {:.9}, \"net_return_s\": {:.9}, \
         \"net_broadcast_per_iter_s\": {:.9}, \"net_return_per_iter_s\": {:.9}, \
         \"net_tasks\": {}, \"net_bodies\": {}, \
         \"iter_p50_s\": {:.9}, \"iter_p90_s\": {:.9}, \"iter_p99_s\": {:.9}, \
         \"wasted_results\": {}, \"wasted_bytes\": {}, \"wasted_compute_s\": {:.9}, \
         \"front_p50_s\": {:.9}, \"front_p99_s\": {:.9}, \"tail_learner\": {}, \
         \"tail_p99_s\": {:.9}, \"injected_share\": {:.6}, \"wall_s\": {:.6}}}",
        c.scheme.name(),
        c.k,
        c.mean_iter.as_secs_f64(),
        c.mean_wait.as_secs_f64(),
        c.total.as_secs_f64(),
        c.wait.as_secs_f64(),
        c.measured_iters,
        c.redundancy,
        c.tolerance,
        c.decode_plan.hits,
        c.decode_plan.misses,
        c.net.broadcast().as_secs_f64(),
        c.net.ret().as_secs_f64(),
        per_iter(c.net.broadcast()),
        per_iter(c.net.ret()),
        c.net.tasks,
        c.net.bodies,
        finite_or_zero(c.iter_q.p50()),
        finite_or_zero(c.iter_q.p90()),
        finite_or_zero(c.iter_q.p99()),
        c.waste.results,
        c.waste.bytes,
        c.waste.compute_secs(),
        finite_or_zero(c.attr.front_p50_s),
        finite_or_zero(c.attr.front_p99_s),
        c.attr.tail_learner.map_or("null".to_string(), |j| j.to_string()),
        finite_or_zero(c.attr.tail_p99_s),
        finite_or_zero(c.attr.injected_share),
        c.wall.as_secs_f64(),
    )
}

/// Machine-readable perf record (`BENCH_sweep.json`): per-cell means,
/// decode-plan cache counters, and wall-clock — written by `sim-sweep`
/// so the perf trajectory is tracked across PRs (the values are plain
/// enum names and finite numbers; no string escaping is needed).
pub fn write_bench_json(
    cells: &[SweepCell],
    wall: Duration,
    path: impl AsRef<std::path::Path>,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let hits: u64 = cells.iter().map(|c| c.decode_plan.hits).sum();
    let misses: u64 = cells.iter().map(|c| c.decode_plan.misses).sum();
    let rate = if hits + misses == 0 { 0.0 } else { hits as f64 / (hits + misses) as f64 };
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"sim_sweep\",")?;
    writeln!(f, "  \"wall_s\": {:.6},", wall.as_secs_f64())?;
    writeln!(f, "  \"simulated_s\": {:.6},", simulated_total(cells).as_secs_f64())?;
    writeln!(f, "  \"decode_plan_hits\": {hits},")?;
    writeln!(f, "  \"decode_plan_misses\": {misses},")?;
    writeln!(f, "  \"decode_plan_hit_rate\": {rate:.6},")?;
    writeln!(f, "  \"cells\": [")?;
    for (i, c) in cells.iter().enumerate() {
        let comma = if i + 1 == cells.len() { "" } else { "," };
        writeln!(f, "    {}{comma}", cell_json(c))?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    f.flush()
}

// ------------------------------------------------------------------
// System-model sweeps: bandwidth axis + BENCH_model.json
// ------------------------------------------------------------------

/// One bandwidth point of a system-model sweep: a full schemes × k
/// grid run with `base.net.bandwidth_mbps` overridden.
pub struct ModelSweepPoint {
    /// Link bandwidth in MB/s; 0 = infinite.
    pub bandwidth_mbps: f64,
    pub cells: Vec<SweepCell>,
    /// Wall-clock spent on this point.
    pub wall: Duration,
}

/// The bandwidth sweep axis (`--bandwidth-list`): run the grid once
/// per bandwidth. Everything else of the base config (trace, jitter,
/// compute model, scheme seeds) is shared, so the points isolate the
/// bandwidth sensitivity — coded schemes' N·header + 1·body broadcast
/// vs uncoded's smaller bodies.
pub fn run_bandwidth_sweep(
    sweep: &SweepConfig,
    bandwidths: &[f64],
) -> Result<Vec<ModelSweepPoint>> {
    bandwidths
        .iter()
        .enumerate()
        .map(|(i, &bw)| {
            let wall_t = std::time::Instant::now();
            let mut base = sweep.base.clone();
            base.net.bandwidth_mbps = bw;
            // Only the first point's first cell traces — every point
            // tracing would overwrite one `trace_out` file per point.
            if i > 0 {
                base.trace_out = None;
            }
            let cells = run_sweep(&SweepConfig {
                base,
                spec: sweep.spec.clone(),
                schemes: sweep.schemes.clone(),
                ks: sweep.ks.clone(),
                delay: sweep.delay,
                artifacts_dir: sweep.artifacts_dir.clone(),
            })
            .with_context(|| format!("bandwidth point {bw} MB/s"))?;
            Ok(ModelSweepPoint { bandwidth_mbps: bw, cells, wall: wall_t.elapsed() })
        })
        .collect()
}

fn bandwidth_label(mbps: f64) -> String {
    if mbps == 0.0 { "bw=inf".into() } else { format!("bw={mbps}MB/s") }
}

/// Bandwidth-sensitivity table: mean iteration time per (scheme, k)
/// row across the bandwidth points.
pub fn bandwidth_table(points: &[ModelSweepPoint]) -> String {
    let mut headers: Vec<String> = vec!["scheme".into(), "k".into()];
    headers.extend(points.iter().map(|p| bandwidth_label(p.bandwidth_mbps)));
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    let Some(first) = points.first() else {
        return table.render();
    };
    for cell in &first.cells {
        let mut row = vec![cell.scheme.name().to_string(), cell.k.to_string()];
        for p in points {
            match p.cells.iter().find(|c| c.scheme == cell.scheme && c.k == cell.k) {
                Some(c) => row.push(format!("{:.1}ms", c.mean_iter.as_secs_f64() * 1e3)),
                None => row.push("-".into()),
            }
        }
        table.row(&row);
    }
    table.render()
}

/// Minimal JSON string escaping (paths can carry anything).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Machine-readable system-model record (`BENCH_model.json`): the
/// active model knobs, grid-level per-iteration statistics (every
/// cell's [`Stats`] merged via [`Stats::merge`]), and per-bandwidth
/// cell lists with the network transfer legs — written by `sim-sweep`
/// whenever a system-model knob is active.
pub fn write_model_json(
    points: &[ModelSweepPoint],
    base: &TrainConfig,
    wall: Duration,
    path: impl AsRef<std::path::Path>,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let all_cells: Vec<&SweepCell> = points.iter().flat_map(|p| p.cells.iter()).collect();
    let mut iter_stats = Stats::new();
    for c in &all_cells {
        iter_stats.merge(&c.iter_stats);
    }
    let simulated: Duration = points.iter().map(|p| simulated_total(&p.cells)).sum();
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"model_sweep\",")?;
    writeln!(f, "  \"wall_s\": {:.6},", wall.as_secs_f64())?;
    writeln!(f, "  \"simulated_s\": {:.6},", simulated.as_secs_f64())?;
    match &base.trace {
        Some(p) => writeln!(f, "  \"trace\": {},", json_str(&p.display().to_string()))?,
        None => writeln!(f, "  \"trace\": null,")?,
    }
    writeln!(f, "  \"net_jitter_us\": {},", base.net.jitter.as_micros())?;
    writeln!(f, "  \"compute_model\": \"{}\",", base.compute_model.name())?;
    if iter_stats.count() > 0 {
        writeln!(f, "  \"iter_mean_s\": {:.9},", iter_stats.mean())?;
        writeln!(f, "  \"iter_std_s\": {:.9},", iter_stats.std())?;
        writeln!(f, "  \"iter_min_s\": {:.9},", iter_stats.min())?;
        writeln!(f, "  \"iter_max_s\": {:.9},", iter_stats.max())?;
    }
    writeln!(f, "  \"iters\": {},", iter_stats.count())?;
    writeln!(f, "  \"points\": [")?;
    for (i, p) in points.iter().enumerate() {
        let comma = if i + 1 == points.len() { "" } else { "," };
        writeln!(f, "    {{")?;
        writeln!(f, "      \"bandwidth_mbps\": {},", p.bandwidth_mbps)?;
        writeln!(f, "      \"wall_s\": {:.6},", p.wall.as_secs_f64())?;
        writeln!(f, "      \"cells\": [")?;
        for (j, c) in p.cells.iter().enumerate() {
            let ccomma = if j + 1 == p.cells.len() { "" } else { "," };
            writeln!(f, "        {}{ccomma}", cell_json(c))?;
        }
        writeln!(f, "      ]")?;
        writeln!(f, "    }}{comma}")?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    f.flush()
}

// ------------------------------------------------------------------
// Axis selection: one resolver for the sim-sweep dispatch
// ------------------------------------------------------------------

/// Which study a `sim-sweep` invocation runs. Exactly one axis is
/// active per run; [`SweepAxis::resolve`] centralizes the
/// mutual-exclusion rules that used to live as scattered bails in the
/// CLI dispatch, so every conflicting flag pair is rejected in one
/// place (and unit-tested as a table below).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepAxis {
    /// The plain schemes × k straggler grid (a single bandwidth point
    /// of the bandwidth runner).
    Grid,
    /// `--bandwidth-list`: the grid once per bandwidth point.
    Bandwidth,
    /// Crash/omission injection: one survival cell per scheme.
    Fault,
    /// Corruption injection: verified decode + quarantine counters.
    /// Crash/omission knobs *compose* with this axis (the cell records
    /// both counter sets), which is why corruption outranks fault in
    /// the priority order instead of conflicting with it.
    Byzantine,
    /// `--adaptive`: the obs-driven plan selector live.
    Adaptive,
    /// `--pipeline`: serial vs depth-2 double buffering, flat vs
    /// racked topology, per-scheme overlap ratios.
    Pipeline,
}

impl SweepAxis {
    /// Pick the axis implied by the config plus the two CLI-only
    /// signals (`--bandwidth-list` has no `TrainConfig` field and
    /// `--pipeline` is a pure dispatch flag), rejecting conflicting
    /// combinations.
    ///
    /// Priority: pipeline (a deliberate opt-in that tolerates no other
    /// axis) > byzantine > fault > adaptive > bandwidth > grid.
    pub fn resolve(cfg: &TrainConfig, bandwidth_list: bool, pipeline: bool) -> Result<SweepAxis> {
        if pipeline {
            if cfg.corrupt.injects() {
                anyhow::bail!(
                    "--pipeline and corruption injection are separate sim-sweep axes; drop one"
                );
            }
            if cfg.fault.injects() {
                anyhow::bail!(
                    "--pipeline and fault injection are separate sim-sweep axes; drop one"
                );
            }
            if cfg.adaptive {
                anyhow::bail!("--pipeline and --adaptive are separate sim-sweep axes; drop one");
            }
            if bandwidth_list {
                anyhow::bail!(
                    "--pipeline and --bandwidth-list are separate sim-sweep axes; drop one"
                );
            }
            if cfg.trace.is_some() {
                anyhow::bail!(
                    "--pipeline measures the modeled controller pipeline; --trace replays \
                     measured delays — drop one"
                );
            }
            return Ok(SweepAxis::Pipeline);
        }
        if cfg.corrupt.injects() {
            if bandwidth_list {
                anyhow::bail!(
                    "--bandwidth-list and corruption injection are separate axes; drop one"
                );
            }
            if cfg.adaptive {
                anyhow::bail!(
                    "--adaptive and corruption injection are separate sim-sweep axes; drop one"
                );
            }
            return Ok(SweepAxis::Byzantine);
        }
        if cfg.fault.injects() {
            if bandwidth_list {
                anyhow::bail!("--bandwidth-list and fault injection are separate axes; drop one");
            }
            if cfg.adaptive {
                anyhow::bail!(
                    "--adaptive and fault injection are separate sim-sweep axes; drop one"
                );
            }
            return Ok(SweepAxis::Fault);
        }
        if cfg.adaptive {
            if bandwidth_list {
                anyhow::bail!("--bandwidth-list and --adaptive are separate axes; drop one");
            }
            return Ok(SweepAxis::Adaptive);
        }
        Ok(if bandwidth_list { SweepAxis::Bandwidth } else { SweepAxis::Grid })
    }
}

// ------------------------------------------------------------------
// Pipeline sweep axis: serial vs depth-2, flat vs racked
// ------------------------------------------------------------------

/// One point of the pipeline sweep: a full schemes × k grid at a
/// fixed (pipeline depth, topology) pair.
pub struct PipelineSweepPoint {
    /// `TrainConfig::pipeline_depth` active for this point (1 or 2).
    pub depth: usize,
    pub topology: Topology,
    /// Rack-uplink bandwidth active for this point (0 under flat).
    pub uplink_mbps: f64,
    pub cells: Vec<SweepCell>,
    /// Wall-clock spent on this point.
    pub wall: Duration,
}

/// The `--pipeline` axis: the grid at depth 1 (strictly serial) and
/// depth 2 (controller prelude credited against the previous
/// iteration's collect+decode window), on the flat topology and —
/// when the base config carries a racked one — on that racked/incast
/// topology too. Depth and topology are **timing-only** knobs: every
/// point's trained parameters are bitwise identical (pinned by
/// `rust/tests/pipeline_integration.rs`), so the axis isolates the
/// overlap win and the incast cost.
pub fn run_pipeline_sweep(sweep: &SweepConfig) -> Result<Vec<PipelineSweepPoint>> {
    let mut topos: Vec<(Topology, f64)> = vec![(Topology::Flat, 0.0)];
    if sweep.base.topology != Topology::Flat {
        topos.push((sweep.base.topology, sweep.base.uplink_mbps));
    }
    let mut points = Vec::with_capacity(topos.len() * 2);
    for (i, &(topology, uplink_mbps)) in topos.iter().enumerate() {
        for depth in [1usize, 2] {
            let wall_t = std::time::Instant::now();
            let mut base = sweep.base.clone();
            base.topology = topology;
            base.uplink_mbps = uplink_mbps;
            base.pipeline_depth = depth;
            // Only the first point's first cell traces (same rule as
            // the bandwidth axis: one `trace_out` file per run).
            if i > 0 || depth > 1 {
                base.trace_out = None;
            }
            let cells = run_sweep(&SweepConfig {
                base,
                spec: sweep.spec.clone(),
                schemes: sweep.schemes.clone(),
                ks: sweep.ks.clone(),
                delay: sweep.delay,
                artifacts_dir: sweep.artifacts_dir.clone(),
            })
            .with_context(|| {
                format!("pipeline point depth={depth} topology={}", topology.label())
            })?;
            points.push(PipelineSweepPoint {
                depth,
                topology,
                uplink_mbps,
                cells,
                wall: wall_t.elapsed(),
            });
        }
    }
    Ok(points)
}

/// Per-(topology, scheme) pipelining summary: mean non-warmup
/// iteration time at depth 1 vs depth 2 and their ratio. A ratio
/// above 1.0 means depth 2 genuinely overlapped the controller
/// prelude; exactly 1.0 means the run was not prelude-bound (e.g.
/// `--ctrl-compute-us 0`, where both depths are the same schedule by
/// construction).
pub struct OverlapRow {
    pub topology: Topology,
    pub scheme: Scheme,
    pub depth1_mean_s: f64,
    pub depth2_mean_s: f64,
    /// depth1 / depth2 (0 when either side measured nothing).
    pub overlap_ratio: f64,
}

/// Fold a pipeline sweep into its overlap rows, pairing each depth-1
/// point with the depth-2 point of the same topology and aggregating
/// the exact per-cell sums over the k axis (never mean-of-means).
pub fn pipeline_overlap(points: &[PipelineSweepPoint]) -> Vec<OverlapRow> {
    let mut rows = Vec::new();
    for p1 in points.iter().filter(|p| p.depth == 1) {
        let Some(p2) = points.iter().find(|p| p.depth == 2 && p.topology == p1.topology) else {
            continue;
        };
        let mut schemes: Vec<Scheme> = Vec::new();
        for c in &p1.cells {
            if !schemes.contains(&c.scheme) {
                schemes.push(c.scheme);
            }
        }
        for scheme in schemes {
            let mean = |cells: &[SweepCell]| {
                let total: Duration =
                    cells.iter().filter(|c| c.scheme == scheme).map(|c| c.total).sum();
                let iters: usize =
                    cells.iter().filter(|c| c.scheme == scheme).map(|c| c.measured_iters).sum();
                if iters == 0 { 0.0 } else { total.as_secs_f64() / iters as f64 }
            };
            let (d1, d2) = (mean(&p1.cells), mean(&p2.cells));
            rows.push(OverlapRow {
                topology: p1.topology,
                scheme,
                depth1_mean_s: d1,
                depth2_mean_s: d2,
                overlap_ratio: if d2 > 0.0 { d1 / d2 } else { 0.0 },
            });
        }
    }
    rows
}

/// Pipeline-axis table: per (topology, scheme) depth-1 vs depth-2
/// mean iteration time and the overlap ratio.
pub fn pipeline_table(points: &[PipelineSweepPoint]) -> String {
    let mut table = Table::new(&["topology", "scheme", "depth1", "depth2", "overlap"]);
    for r in pipeline_overlap(points) {
        table.row(&[
            r.topology.label(),
            r.scheme.name().to_string(),
            format!("{:.1}ms", r.depth1_mean_s * 1e3),
            format!("{:.1}ms", r.depth2_mean_s * 1e3),
            format!("{:.2}x", r.overlap_ratio),
        ]);
    }
    table.render()
}

/// Machine-readable pipeline record (`BENCH_pipeline.json`): the
/// active pipeline knobs, per-point cell lists, and the
/// per-(topology, scheme) overlap rows CI gates on.
pub fn write_pipeline_json(
    points: &[PipelineSweepPoint],
    base: &TrainConfig,
    wall: Duration,
    path: impl AsRef<std::path::Path>,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let simulated: Duration = points.iter().map(|p| simulated_total(&p.cells)).sum();
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"pipeline_sweep\",")?;
    writeln!(f, "  \"wall_s\": {:.6},", wall.as_secs_f64())?;
    writeln!(f, "  \"simulated_s\": {:.6},", simulated.as_secs_f64())?;
    writeln!(f, "  \"ctrl_compute_us\": {},", base.ctrl_compute.as_micros())?;
    writeln!(f, "  \"decode_threads\": {},", base.decode_threads)?;
    writeln!(f, "  \"topology\": {},", json_str(&base.topology.label()))?;
    writeln!(f, "  \"uplink_mbps\": {},", base.uplink_mbps)?;
    writeln!(f, "  \"points\": [")?;
    for (i, p) in points.iter().enumerate() {
        let comma = if i + 1 == points.len() { "" } else { "," };
        writeln!(f, "    {{")?;
        writeln!(f, "      \"depth\": {},", p.depth)?;
        writeln!(f, "      \"topology\": {},", json_str(&p.topology.label()))?;
        writeln!(f, "      \"uplink_mbps\": {},", p.uplink_mbps)?;
        writeln!(f, "      \"wall_s\": {:.6},", p.wall.as_secs_f64())?;
        writeln!(f, "      \"cells\": [")?;
        for (j, c) in p.cells.iter().enumerate() {
            let ccomma = if j + 1 == p.cells.len() { "" } else { "," };
            writeln!(f, "        {}{ccomma}", cell_json(c))?;
        }
        writeln!(f, "      ]")?;
        writeln!(f, "    }}{comma}")?;
    }
    writeln!(f, "  ],")?;
    let overlap = pipeline_overlap(points);
    writeln!(f, "  \"overlap\": [")?;
    for (i, r) in overlap.iter().enumerate() {
        let comma = if i + 1 == overlap.len() { "" } else { "," };
        writeln!(
            f,
            "    {{\"topology\": {}, \"scheme\": \"{}\", \"depth1_mean_iter_s\": {:.9}, \
             \"depth2_mean_iter_s\": {:.9}, \"overlap_ratio\": {:.6}}}{comma}",
            json_str(&r.topology.label()),
            r.scheme.name(),
            finite_or_zero(r.depth1_mean_s),
            finite_or_zero(r.depth2_mean_s),
            finite_or_zero(r.overlap_ratio),
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    f.flush()
}

// ------------------------------------------------------------------
// Cluster-scale study: schemes × k-fractions × N × delay tails
// ------------------------------------------------------------------

/// The N = 100–10 000 heavy-tail study (ROADMAP "cluster-scale
/// scheduling studies"): for each delay distribution and each learner
/// count, run a full schemes × k sweep with straggler counts expressed
/// as **fractions of N** so the points are comparable across scales.
pub struct ScaleStudyConfig {
    /// Template cell config (seed, iterations, mock_compute, threads,
    /// t_s in `straggler.delay`…); `n_learners` and `straggler.dist`
    /// are overwritten per point.
    pub base: TrainConfig,
    pub spec: RunSpec,
    pub schemes: Vec<Scheme>,
    /// Learner counts to sweep (e.g. `[100, 1000, 10000]`).
    pub ns: Vec<usize>,
    /// Straggler counts as fractions of N (rounded, clamped to N,
    /// deduped after rounding).
    pub k_fracs: Vec<f64>,
    /// Injected mean delay t_s.
    pub delay: Duration,
    /// Delay tails to compare (e.g. fixed vs Pareto).
    pub dists: Vec<DelayDist>,
    pub artifacts_dir: std::path::PathBuf,
}

/// One (N, delay distribution) point: a full schemes × k sweep.
pub struct ScalePoint {
    pub n: usize,
    pub dist: DelayDist,
    /// The realized straggler counts (`k_fracs` × N, deduped).
    pub ks: Vec<usize>,
    pub cells: Vec<SweepCell>,
    /// Wall-clock spent on this point.
    pub wall: Duration,
}

/// Round the k-fractions against a concrete N (sorted, deduped).
pub fn ks_for_n(k_fracs: &[f64], n: usize) -> Vec<usize> {
    let mut ks: Vec<usize> =
        k_fracs.iter().map(|f| ((f * n as f64).round() as usize).min(n)).collect();
    ks.sort_unstable();
    ks.dedup();
    ks
}

/// Run the full study grid. Points run sequentially (each inner sweep
/// already shards its cells across `base.sweep_threads`).
pub fn run_scale_study(cfg: &ScaleStudyConfig) -> Result<Vec<ScalePoint>> {
    let mut points = Vec::with_capacity(cfg.dists.len() * cfg.ns.len());
    for &dist in &cfg.dists {
        for &n in &cfg.ns {
            let wall_t = std::time::Instant::now();
            let mut base = cfg.base.clone();
            base.n_learners = n;
            base.straggler.dist = dist;
            let ks = ks_for_n(&cfg.k_fracs, n);
            let cells = run_sweep(&SweepConfig {
                base,
                spec: cfg.spec.clone(),
                schemes: cfg.schemes.clone(),
                ks: ks.clone(),
                delay: cfg.delay,
                artifacts_dir: cfg.artifacts_dir.clone(),
            })
            .with_context(|| format!("scale point N={n} dist={}", dist.name()))?;
            points.push(ScalePoint { n, dist, ks, cells, wall: wall_t.elapsed() });
        }
    }
    Ok(points)
}

/// The crossover table the study exists for: per (dist, N, k), which
/// scheme wins on mean iteration time, and the LDPC/MDS ratio (< 1 ⇒
/// the sparse code overtakes MDS at that point).
pub fn crossover_summary(points: &[ScalePoint]) -> String {
    let mut table =
        Table::new(&["dist", "N", "k", "winner", "mean_iter", "iter_p99", "ldpc/mds"]);
    for p in points {
        for &k in &p.ks {
            let at = |s: Scheme| p.cells.iter().find(|c| c.scheme == s && c.k == k);
            let Some(winner) = p
                .cells
                .iter()
                .filter(|c| c.k == k)
                .min_by_key(|c| c.mean_iter)
            else {
                continue;
            };
            let ratio = match (at(Scheme::Ldpc), at(Scheme::Mds)) {
                (Some(l), Some(m)) if m.mean_iter > Duration::ZERO => format!(
                    "{:.3}",
                    l.mean_iter.as_secs_f64() / m.mean_iter.as_secs_f64()
                ),
                _ => "-".into(),
            };
            let p99 = if winner.iter_q.count() > 0 && winner.iter_q.p99().is_finite() {
                format!("{:.1}ms", winner.iter_q.p99() * 1e3)
            } else {
                "-".into()
            };
            table.row(&[
                p.dist.label(),
                p.n.to_string(),
                k.to_string(),
                winner.scheme.name().to_string(),
                format!("{:.1}ms", winner.mean_iter.as_secs_f64() * 1e3),
                p99,
                ratio,
            ]);
        }
    }
    table.render()
}

/// Machine-readable study record (`BENCH_scale.json`): one entry per
/// (N, dist) point with its full cell list — written by `coded-marl
/// scale-study` so the crossover trajectory is tracked across PRs.
pub fn write_scale_json(
    points: &[ScalePoint],
    wall: Duration,
    path: impl AsRef<std::path::Path>,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let simulated: Duration = points.iter().map(|p| simulated_total(&p.cells)).sum();
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"scale_study\",")?;
    writeln!(f, "  \"wall_s\": {:.6},", wall.as_secs_f64())?;
    writeln!(f, "  \"simulated_s\": {:.6},", simulated.as_secs_f64())?;
    writeln!(f, "  \"points\": [")?;
    for (i, p) in points.iter().enumerate() {
        let comma = if i + 1 == points.len() { "" } else { "," };
        writeln!(f, "    {{")?;
        writeln!(f, "      \"n\": {},", p.n)?;
        writeln!(f, "      \"dist\": \"{}\",", p.dist.name())?;
        match p.dist {
            DelayDist::Pareto { alpha } => writeln!(f, "      \"alpha\": {alpha},")?,
            DelayDist::LogNormal { sigma } => writeln!(f, "      \"sigma\": {sigma},")?,
            _ => {}
        }
        writeln!(f, "      \"wall_s\": {:.6},", p.wall.as_secs_f64())?;
        writeln!(f, "      \"cells\": [")?;
        for (j, c) in p.cells.iter().enumerate() {
            let ccomma = if j + 1 == p.cells.len() { "" } else { "," };
            writeln!(f, "        {}{ccomma}", cell_json(c))?;
        }
        writeln!(f, "      ]")?;
        writeln!(f, "    }}{comma}")?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    f.flush()
}

// ------------------------------------------------------------------
// Fault-tolerance sweeps: crash/omission axis + BENCH_fault.json
// ------------------------------------------------------------------

/// One scheme's outcome under the sweep's fault configuration: how far
/// the run got, whether it survived (possibly degraded), and the
/// controller's fault-lifecycle counters.
pub struct FaultCell {
    pub scheme: Scheme,
    /// Iterations that completed before the run ended (= the target on
    /// a survived run).
    pub iters_done: usize,
    /// Scheduled iterations (`base.iterations`).
    pub iters_target: usize,
    /// `iters_done / iters_target` — the headline availability number.
    pub availability: f64,
    /// Whether the run reached its final iteration. A `false` cell
    /// terminated **deterministically** through the degraded path
    /// ([`FaultError`]) — a hang to `collect_timeout` is a bug, not a
    /// cell outcome.
    pub survived: bool,
    /// The [`FaultError`] rendering when the run terminated early.
    pub error: Option<String>,
    /// Losses / suspicions / deaths / remaps / degraded retries /
    /// recovery time accumulated by the controller.
    pub stats: FaultStats,
    /// Worst-case crash tolerance of the scheme's assignment matrix.
    pub tolerance: usize,
    /// Wall-clock spent executing the cell (not simulated time).
    pub wall: Duration,
}

/// Run one scheme under the base config's fault knobs. A [`FaultError`]
/// is a *cell outcome* (degraded, recorded), not a sweep failure; any
/// other error propagates — it is a bug.
fn run_fault_cell(sweep: &SweepConfig, scheme: Scheme) -> Result<FaultCell> {
    let wall_t = std::time::Instant::now();
    let mut cfg = sweep.base.clone();
    cfg.scheme = scheme;
    cfg.trace_out = None; // one trace file; fault cells never trace
    cfg.straggler.delay = sweep.delay;
    cfg.seed = derive_scheme_seed(sweep.base.seed, scheme);
    let code = Code::build(&CodeParams {
        scheme,
        n: cfg.n_learners,
        m: sweep.spec.m,
        p_m: cfg.p_m,
        seed: cfg.seed,
    });
    let tolerance = code.worst_case_tolerance();
    let factory = backend_factory(&cfg, sweep.artifacts_dir.clone(), &sweep.spec);
    let pool = spawn_pool(&cfg, factory)?;
    let iters_target = cfg.iterations;
    let mut ctrl = Controller::new(cfg, sweep.spec.clone(), pool)
        .with_context(|| format!("building fault cell for {scheme}"))?;
    let res = ctrl.train().map(|_| ());
    let iters_done = ctrl.log.len();
    let stats = ctrl.fault_stats();
    ctrl.shutdown();
    let (survived, error) = match res {
        Ok(()) => (true, None),
        Err(e) => match e.downcast_ref::<FaultError>() {
            Some(fe) => (false, Some(fe.to_string())),
            None => {
                return Err(e).with_context(|| format!("fault cell {scheme} died unexpectedly"))
            }
        },
    };
    Ok(FaultCell {
        scheme,
        iters_done,
        iters_target,
        availability: if iters_target == 0 {
            0.0
        } else {
            iters_done as f64 / iters_target as f64
        },
        survived,
        error,
        stats,
        tolerance,
        wall: wall_t.elapsed(),
    })
}

/// The fault axis: one cell per scheme, all under `base.fault`. Serial
/// — fault sweeps are short and their value is the per-scheme
/// comparison, not throughput.
pub fn run_fault_sweep(sweep: &SweepConfig) -> Result<Vec<FaultCell>> {
    sweep.schemes.iter().map(|&s| run_fault_cell(sweep, s)).collect()
}

/// Fault-sweep table: survival, availability, deaths/remaps, recovery.
pub fn fault_table(cells: &[FaultCell]) -> String {
    let mut table = Table::new(&[
        "scheme",
        "tolerance",
        "iters",
        "availability",
        "lost",
        "deaths",
        "remaps",
        "degraded",
        "recovery",
        "outcome",
    ]);
    for c in cells {
        table.row(&[
            c.scheme.name().to_string(),
            c.tolerance.to_string(),
            format!("{}/{}", c.iters_done, c.iters_target),
            format!("{:.2}", c.availability),
            c.stats.lost_results.to_string(),
            c.stats.deaths.to_string(),
            c.stats.remaps.to_string(),
            c.stats.degraded_iters.to_string(),
            format!("{:.1}ms", c.stats.recovery_ns as f64 / 1e6),
            if c.survived { "survived".into() } else { "degraded-stop".into() },
        ]);
    }
    table.render()
}

/// Machine-readable fault record (`BENCH_fault.json`): the fault knobs
/// and one cell per scheme with iterations survived, availability, and
/// recovery time — written by `sim-sweep` whenever a fault knob is
/// active.
pub fn write_fault_json(
    cells: &[FaultCell],
    base: &TrainConfig,
    wall: Duration,
    path: impl AsRef<std::path::Path>,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"fault_sweep\",")?;
    writeln!(f, "  \"wall_s\": {:.6},", wall.as_secs_f64())?;
    writeln!(f, "  \"crash_rate\": {},", base.fault.crash_rate)?;
    match base.fault.crash_restart {
        Some(d) => writeln!(f, "  \"crash_restart_s\": {:.6},", d.as_secs_f64())?,
        None => writeln!(f, "  \"crash_restart_s\": null,")?,
    }
    writeln!(f, "  \"omission_rate\": {},", base.fault.omission_rate)?;
    writeln!(f, "  \"degraded_mode\": \"{}\",", base.fault.degraded.name())?;
    writeln!(f, "  \"cells\": [")?;
    for (i, c) in cells.iter().enumerate() {
        let comma = if i + 1 == cells.len() { "" } else { "," };
        writeln!(
            f,
            "    {{\"scheme\": \"{}\", \"tolerance\": {}, \"iters_done\": {}, \
             \"iters_target\": {}, \"availability\": {:.6}, \"survived\": {}, \
             \"lost_results\": {}, \"suspected\": {}, \"deaths\": {}, \"remaps\": {}, \
             \"degraded_iters\": {}, \"recovery_s\": {:.9}, \"error\": {}, \
             \"wall_s\": {:.6}}}{comma}",
            c.scheme.name(),
            c.tolerance,
            c.iters_done,
            c.iters_target,
            c.availability,
            c.survived,
            c.stats.lost_results,
            c.stats.suspected,
            c.stats.deaths,
            c.stats.remaps,
            c.stats.degraded_iters,
            c.stats.recovery_ns as f64 / 1e9,
            c.error.as_deref().map_or("null".to_string(), json_str),
            c.wall.as_secs_f64(),
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    f.flush()
}

// ------------------------------------------------------------------
// Adaptive-plan sweeps: obs-driven scheme switching + BENCH_adaptive.json
// ------------------------------------------------------------------

/// One starting scheme's outcome with the adaptive selector live:
/// where the plan ended up, how many times it was rebuilt, and what
/// the run cost. The axis answers the headline question of the
/// adaptive layer — does the obs-driven selector move off a
/// mis-provisioned scheme, and does the run stay sound while results
/// encoded under old plans race the switch?
pub struct AdaptiveCell {
    /// Scheme the run was provisioned with (`cfg.scheme` at start).
    pub start_scheme: Scheme,
    /// Scheme the live plan held when the run finished.
    pub final_scheme: Scheme,
    /// Plan epoch at the end of the run — the number of plan installs.
    /// Fault knobs are normally off in this axis, so every install is
    /// an adaptive switch; with faults on it also counts remaps.
    pub final_epoch: u16,
    /// Exact summed training time over the non-warmup iterations.
    pub total: Duration,
    /// Mean per-iteration training time (derived, display only).
    pub mean_iter: Duration,
    /// Iterations averaged over (excludes warmup).
    pub measured_iters: usize,
    /// Wasted arrivals over the run — includes every cross-epoch
    /// result that raced a plan switch (classified stale, never
    /// decoded).
    pub waste: WasteStats,
    /// Wall-clock spent executing the cell (not simulated time).
    pub wall: Duration,
}

/// Run one starting scheme with the adaptive selector forced on. The
/// disturbance comes from the recorded trace when `base.trace` is set
/// (the regime-shift proof), else from the synthetic injector with the
/// sweep's delay.
fn run_adaptive_cell(sweep: &SweepConfig, scheme: Scheme) -> Result<AdaptiveCell> {
    let wall_t = std::time::Instant::now();
    let mut cfg = sweep.base.clone();
    cfg.scheme = scheme;
    cfg.adaptive = true;
    cfg.trace_out = None; // one trace file; adaptive cells never trace
    if cfg.trace.is_none() {
        cfg.straggler.delay = sweep.delay;
    }
    cfg.seed = derive_scheme_seed(sweep.base.seed, scheme);
    let factory = backend_factory(&cfg, sweep.artifacts_dir.clone(), &sweep.spec);
    let pool = spawn_pool(&cfg, factory)?;
    let mut ctrl = Controller::new(cfg, sweep.spec.clone(), pool)
        .with_context(|| format!("building adaptive cell for {scheme}"))?;
    ctrl.train().with_context(|| format!("training adaptive cell {scheme}"))?;
    let nw = mean_non_warmup(&ctrl.log);
    let final_scheme = ctrl.current_scheme();
    let final_epoch = ctrl.plan_epoch();
    let waste = ctrl.waste_stats();
    ctrl.shutdown();
    Ok(AdaptiveCell {
        start_scheme: scheme,
        final_scheme,
        final_epoch,
        total: nw.total,
        mean_iter: nw.mean_total(),
        measured_iters: nw.iters,
        waste,
        wall: wall_t.elapsed(),
    })
}

/// The adaptive axis: one cell per *starting* scheme, selector live in
/// every cell. Serial — like the fault axis, its value is the
/// per-scheme comparison, not throughput (and each cell's selector
/// already decides from its own seeded stream, so serial execution
/// costs nothing in determinism).
pub fn run_adaptive_sweep(sweep: &SweepConfig) -> Result<Vec<AdaptiveCell>> {
    sweep.schemes.iter().map(|&s| run_adaptive_cell(sweep, s)).collect()
}

/// Adaptive-sweep table: start → final scheme, plan installs, timing,
/// waste.
pub fn adaptive_table(cells: &[AdaptiveCell]) -> String {
    let mut table = Table::new(&[
        "start",
        "final",
        "switches",
        "mean_iter",
        "iters",
        "wasted",
        "wasted_compute",
    ]);
    for c in cells {
        table.row(&[
            c.start_scheme.name().to_string(),
            c.final_scheme.name().to_string(),
            c.final_epoch.to_string(),
            format!("{:.1}ms", c.mean_iter.as_secs_f64() * 1e3),
            c.measured_iters.to_string(),
            c.waste.results.to_string(),
            format!("{:.1}ms", c.waste.compute_secs() * 1e3),
        ]);
    }
    table.render()
}

/// Machine-readable adaptive record (`BENCH_adaptive.json`): the
/// estimator knobs and one cell per starting scheme with the final
/// plan parameters and switch count — written by `sim-sweep` whenever
/// `--adaptive` is set, and consumed by the CI smoke gate that asserts
/// the selector actually moved on a regime-shifting trace.
pub fn write_adaptive_json(
    cells: &[AdaptiveCell],
    base: &TrainConfig,
    wall: Duration,
    path: impl AsRef<std::path::Path>,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"adaptive_sweep\",")?;
    writeln!(f, "  \"wall_s\": {:.6},", wall.as_secs_f64())?;
    writeln!(f, "  \"adapt_every\": {},", base.adapt_every)?;
    writeln!(f, "  \"adapt_min_obs\": {},", base.adapt_min_obs)?;
    writeln!(f, "  \"adapt_hysteresis\": {},", base.adapt_hysteresis)?;
    match &base.trace {
        Some(p) => writeln!(f, "  \"trace\": {},", json_str(&p.display().to_string()))?,
        None => writeln!(f, "  \"trace\": null,")?,
    }
    writeln!(f, "  \"cells\": [")?;
    for (i, c) in cells.iter().enumerate() {
        let comma = if i + 1 == cells.len() { "" } else { "," };
        writeln!(
            f,
            "    {{\"start_scheme\": \"{}\", \"final_scheme\": \"{}\", \
             \"plan_switches\": {}, \"switched\": {}, \"mean_iter_s\": {:.9}, \
             \"total_s\": {:.9}, \"iters\": {}, \"wasted_results\": {}, \
             \"wasted_bytes\": {}, \"wasted_compute_s\": {:.9}, \
             \"wall_s\": {:.6}}}{comma}",
            c.start_scheme.name(),
            c.final_scheme.name(),
            c.final_epoch,
            c.final_epoch > 0,
            c.mean_iter.as_secs_f64(),
            c.total.as_secs_f64(),
            c.measured_iters,
            c.waste.results,
            c.waste.bytes,
            c.waste.compute_secs(),
            c.wall.as_secs_f64(),
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    f.flush()
}

// ------------------------------------------------------------------
// Byzantine sweeps: corruption + verified decode + BENCH_byzantine.json
// ------------------------------------------------------------------

/// One scheme's outcome under the sweep's corruption configuration
/// with the verified decoder live: how much injected corruption the
/// residual parity check saw, caught, and attributed, and whether the
/// run survived to the end.
pub struct ByzantineCell {
    pub scheme: Scheme,
    /// Iterations that completed before the run ended.
    pub iters_done: usize,
    /// Scheduled iterations (`base.iterations`).
    pub iters_target: usize,
    /// Whether the run reached its final iteration (a `false` cell
    /// terminated deterministically through the degraded path).
    pub survived: bool,
    /// The [`FaultError`] rendering when the run terminated early.
    pub error: Option<String>,
    /// Corruption seen/detected/identified counters from the verified
    /// decoder plus quarantine outcomes.
    pub byz: ByzantineStats,
    /// The crash/omission lifecycle counters — context for runs mixing
    /// corruption with loss faults.
    pub faults: FaultStats,
    /// Worst-case straggler (erasure) tolerance of the assignment
    /// matrix: the surplus the verifier can spend.
    pub tolerance: usize,
    /// Worst-case guaranteed error-correction budget `e`: each located
    /// error costs one exclusion *and* one surviving parity row, so
    /// `e = ⌊tolerance / 2⌋` when every learner reports.
    pub correction_budget: usize,
    /// Wall-clock spent executing the cell (not simulated time).
    pub wall: Duration,
}

/// Run one scheme under the base config's corruption knobs with
/// `--verify-decode` forced on (the axis *is* verification — a
/// corruption sweep without the checker would just measure silent
/// poisoning). A [`FaultError`] is a cell outcome, not a sweep failure.
fn run_byzantine_cell(sweep: &SweepConfig, scheme: Scheme) -> Result<ByzantineCell> {
    let wall_t = std::time::Instant::now();
    let mut cfg = sweep.base.clone();
    cfg.scheme = scheme;
    cfg.verify_decode = true;
    cfg.trace_out = None; // one trace file; byzantine cells never trace
    cfg.straggler.delay = sweep.delay;
    cfg.seed = derive_scheme_seed(sweep.base.seed, scheme);
    let code = Code::build(&CodeParams {
        scheme,
        n: cfg.n_learners,
        m: sweep.spec.m,
        p_m: cfg.p_m,
        seed: cfg.seed,
    });
    let tolerance = code.worst_case_tolerance();
    let factory = backend_factory(&cfg, sweep.artifacts_dir.clone(), &sweep.spec);
    let pool = spawn_pool(&cfg, factory)?;
    let iters_target = cfg.iterations;
    let mut ctrl = Controller::new(cfg, sweep.spec.clone(), pool)
        .with_context(|| format!("building byzantine cell for {scheme}"))?;
    let res = ctrl.train().map(|_| ());
    let iters_done = ctrl.log.len();
    let byz = ctrl.byzantine_stats();
    let faults = ctrl.fault_stats();
    ctrl.shutdown();
    let (survived, error) = match res {
        Ok(()) => (true, None),
        Err(e) => match e.downcast_ref::<FaultError>() {
            Some(fe) => (false, Some(fe.to_string())),
            None => {
                return Err(e)
                    .with_context(|| format!("byzantine cell {scheme} died unexpectedly"))
            }
        },
    };
    Ok(ByzantineCell {
        scheme,
        iters_done,
        iters_target,
        survived,
        error,
        byz,
        faults,
        tolerance,
        correction_budget: tolerance / 2,
        wall: wall_t.elapsed(),
    })
}

/// The byzantine axis: one cell per scheme, all under `base.corrupt`
/// with verification on. Serial — like the fault axis, its value is
/// the per-scheme comparison, not throughput.
pub fn run_byzantine_sweep(sweep: &SweepConfig) -> Result<Vec<ByzantineCell>> {
    sweep.schemes.iter().map(|&s| run_byzantine_cell(sweep, s)).collect()
}

/// Byzantine-sweep table: correction budget, corruption seen vs
/// caught, attribution quality, quarantines.
pub fn byzantine_table(cells: &[ByzantineCell]) -> String {
    let mut table = Table::new(&[
        "scheme",
        "budget",
        "iters",
        "seen",
        "detected",
        "identified",
        "miscorrected",
        "unresolved",
        "quarantined",
        "locate_decodes",
        "outcome",
    ]);
    for c in cells {
        table.row(&[
            c.scheme.name().to_string(),
            format!("e≤{}", c.correction_budget),
            format!("{}/{}", c.iters_done, c.iters_target),
            c.byz.corrupted_seen.to_string(),
            c.byz.detected.to_string(),
            c.byz.identified.to_string(),
            c.byz.miscorrected.to_string(),
            c.byz.unresolved.to_string(),
            c.byz.quarantined.to_string(),
            c.byz.locate_decodes.to_string(),
            if c.survived { "survived".into() } else { "degraded-stop".into() },
        ]);
    }
    table.render()
}

/// Machine-readable byzantine record (`BENCH_byzantine.json`): the
/// corruption knobs and one cell per scheme with the detection /
/// attribution / quarantine counters — written by `sim-sweep` whenever
/// a corruption knob is active, and consumed by the CI smoke gate that
/// asserts redundant schemes actually catch what was injected.
pub fn write_byzantine_json(
    cells: &[ByzantineCell],
    base: &TrainConfig,
    wall: Duration,
    path: impl AsRef<std::path::Path>,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{{")?;
    writeln!(f, "  \"bench\": \"byzantine_sweep\",")?;
    writeln!(f, "  \"wall_s\": {:.6},", wall.as_secs_f64())?;
    writeln!(f, "  \"corrupt_rate\": {},", base.corrupt.rate)?;
    writeln!(f, "  \"corrupt_mode\": \"{}\",", base.corrupt.mode.name())?;
    writeln!(f, "  \"cells\": [")?;
    for (i, c) in cells.iter().enumerate() {
        let comma = if i + 1 == cells.len() { "" } else { "," };
        writeln!(
            f,
            "    {{\"scheme\": \"{}\", \"tolerance\": {}, \"correction_budget\": {}, \
             \"iters_done\": {}, \"iters_target\": {}, \"survived\": {}, \
             \"corrupted_seen\": {}, \"verify_failures\": {}, \"detected\": {}, \
             \"identified\": {}, \"miscorrected\": {}, \"unresolved\": {}, \
             \"quarantined\": {}, \"surplus_rows\": {}, \"locate_decodes\": {}, \
             \"deaths\": {}, \"remaps\": {}, \"error\": {}, \"wall_s\": {:.6}}}{comma}",
            c.scheme.name(),
            c.tolerance,
            c.correction_budget,
            c.iters_done,
            c.iters_target,
            c.survived,
            c.byz.corrupted_seen,
            c.byz.verify_failures,
            c.byz.detected,
            c.byz.identified,
            c.byz.miscorrected,
            c.byz.unresolved,
            c.byz.quarantined,
            c.byz.surplus_rows,
            c.byz.locate_decodes,
            c.faults.deaths,
            c.faults.remaps,
            c.error.as_deref().map_or("null".to_string(), json_str),
            c.wall.as_secs_f64(),
        )?;
    }
    writeln!(f, "  ]")?;
    writeln!(f, "}}")?;
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::EnvKind;

    fn base() -> TrainConfig {
        let mut cfg = sweep_base("synthetic", 7, 3, Duration::from_millis(2), 9);
        cfg.episode_len = 5;
        cfg
    }

    #[test]
    fn sweep_base_sets_the_virtual_protocol() {
        let cfg = base();
        assert_eq!(cfg.time_mode, TimeMode::Virtual);
        assert_eq!(cfg.backend, Backend::Mock);
        assert_eq!(cfg.iterations, 4, "3 measured + 1 warmup");
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn sweep_covers_the_grid_and_orders_cells() {
        let sweep = SweepConfig {
            base: base(),
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Uncoded, Scheme::Mds],
            // k = 3 is within MDS's worst-case tolerance (N−M = 3) but
            // k = N hits every learner — both deterministic outcomes.
            ks: vec![3, 7],
            delay: Duration::from_millis(40),
            artifacts_dir: "artifacts".into(),
        };
        let cells = run_sweep(&sweep).unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].scheme, Scheme::Uncoded);
        assert_eq!(cells[0].k, 3);
        assert_eq!(cells[3].scheme, Scheme::Mds);
        assert_eq!(cells[3].k, 7);
        assert!(cells.iter().all(|c| c.measured_iters == 3));
        // k = N stalls every scheme for the full t_s…
        let unc_all = &cells[1];
        assert!(
            unc_all.mean_iter >= Duration::from_millis(40),
            "uncoded with all learners straggling must wait out t_s, got {:?}",
            unc_all.mean_iter
        );
        // …while MDS masks k ≤ N−M regardless of which learners are hit
        let mds_k3 = &cells[2];
        assert!(
            mds_k3.mean_iter < Duration::from_millis(40),
            "MDS must mask 3 stragglers, got {:?}",
            mds_k3.mean_iter
        );
        assert_eq!(mds_k3.tolerance, 3);
        // Observability rides on every cell: per-iteration quantiles
        // over exactly the measured iterations, finite and ordered…
        for c in &cells {
            assert_eq!(c.iter_q.count(), 3, "{}/{}", c.scheme, c.k);
            let (p50, p99) = (c.iter_q.p50(), c.iter_q.p99());
            assert!(p50.is_finite() && p99.is_finite() && p50 <= p99, "{}/{}", c.scheme, c.k);
            assert!((c.iter_q.p99() - c.iter_stats.max()).abs() < 1e-12, "3 samples: p99 = max");
        }
        // …attribution: with every learner injected, every used arrival
        // is injected; the k = N cell waits out t_s so the front is
        // (near-)zero only when arrivals are simultaneous.
        assert_eq!(unc_all.attr.injected_share, 1.0, "k = N ⇒ all used arrivals injected");
        assert!(unc_all.attr.tail_learner.is_some());
        // …and wasted work: MDS reaches decodability while 3 straggler
        // results are still in flight — they are cancelled or arrive
        // post-decodable, either way counted as waste.
        assert!(
            mds_k3.waste.results > 0,
            "straggler results past decodability must be accounted as waste"
        );
        assert!(mds_k3.waste.bytes > 0);
        let txt = render_table(&cells, &sweep.ks);
        assert!(txt.contains("uncoded") && txt.contains("mds"));
        assert!(txt.contains("iter p50/p99"), "tail column present:\n{txt}");
        let (lo, hi) = grid_p99_range(&cells).expect("measured cells");
        assert!(lo <= hi && lo > 0.0);
    }

    fn cell(scheme: Scheme, k: usize) -> SweepCell {
        let mut iter_stats = Stats::new();
        let mut iter_q = Quantiles::new();
        for _ in 0..5 {
            iter_stats.push(0.012);
            iter_q.push(0.012);
        }
        SweepCell {
            scheme,
            k,
            total: Duration::from_millis(60),
            wait: Duration::from_millis(45),
            mean_iter: Duration::from_millis(12),
            mean_wait: Duration::from_millis(9),
            measured_iters: 5,
            redundancy: 2.5,
            tolerance: 3,
            decode_plan: PlanCacheStats { hits: 4, misses: 1, entries: 1 },
            net: NetStats::default(),
            iter_stats,
            iter_q,
            waste: WasteStats { results: 2, bytes: 100, compute_ns: 3_000_000 },
            attr: AttrSummary { tail_learner: Some(5), tail_p99_s: 0.040, ..Default::default() },
            wall: Duration::from_millis(3),
        }
    }

    /// Regression (ISSUE 3): `simulated_total` must consume the exact
    /// per-cell sums. The old `mean_iter × iters` form (a) re-truncated
    /// an already floor-divided mean and (b) panicked on `Duration ×
    /// u32` overflow for large virtual-time cells.
    #[test]
    fn simulated_total_is_exact_and_overflow_safe() {
        // (a) truncation: 10 ns over 3 iters → mean floors to 3 ns; the
        // old formula reported 9 ns. The exact total must survive.
        let mut c = cell(Scheme::Mds, 0);
        c.total = Duration::from_nanos(10);
        c.measured_iters = 3;
        c.mean_iter = c.total / 3; // 3 ns (floored), display only
        assert_eq!(simulated_total(&[c]), Duration::from_nanos(10));
        // (b) overflow: a mean whose × iters blows past Duration. The
        // exact-sum path never touches that product.
        let mut c = cell(Scheme::Mds, 0);
        c.mean_iter = Duration::MAX / 2;
        c.measured_iters = 1000; // old: (MAX/2) × 1000 → panic
        c.total = Duration::from_secs(86_400); // the exact simulated sum
        let mut d = cell(Scheme::Ldpc, 1);
        d.total = Duration::from_secs(13);
        assert_eq!(simulated_total(&[c, d]), Duration::from_secs(86_413));
    }

    /// Regression (ISSUE 3): `mean_non_warmup` returns the exact sums;
    /// means are derived accessors, never part of downstream math.
    #[test]
    fn mean_non_warmup_returns_exact_sums() {
        use crate::metrics::{IterRecord, IterTiming};
        let mut log = RunLog::new();
        let mut push = |iter: u64, total_ns: u64, wait_ns: u64, method: &'static str| {
            let timing = IterTiming {
                total: Duration::from_nanos(total_ns),
                wait: Duration::from_nanos(wait_ns),
                ..Default::default()
            };
            log.push(IterRecord {
                iter,
                timing,
                reward: 0.0,
                critic_loss: f64::NAN,
                results_used: 0,
                decode_method: method,
                stragglers: Vec::new(),
            });
        };
        push(0, 999, 999, "warmup"); // excluded
        push(1, 5, 2, "qr");
        push(2, 5, 2, "qr");
        push(3, 7, 3, "qr");
        let nw = mean_non_warmup(&log);
        assert_eq!(nw.iters, 3);
        assert_eq!(nw.total, Duration::from_nanos(17), "exact, not mean×n");
        assert_eq!(nw.wait, Duration::from_nanos(7));
        // the displayed means floor…
        assert_eq!(nw.mean_total(), Duration::from_nanos(5));
        assert_eq!(nw.mean_wait(), Duration::from_nanos(2));
        // …and an empty log yields zeros without dividing by zero
        let empty = mean_non_warmup(&RunLog::new());
        assert_eq!((empty.iters, empty.mean_total()), (0, Duration::ZERO));
    }

    #[test]
    fn csv_roundtrip() {
        let cells = vec![cell(Scheme::Mds, 2)];
        let dir = std::env::temp_dir().join("coded_marl_sweep_csv_test");
        let path = dir.join("sweep.csv");
        write_csv(&cells, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("mds,2,0.012"));
        let header = text.lines().next().unwrap();
        assert!(header.contains("decode_plan_hits"));
        assert!(text.contains(",4,1"), "cache counters must be recorded: {text}");
        // Observability columns ride at the END of the row so existing
        // consumers keep their positional reads.
        assert!(header.ends_with(
            "iter_p50_s,iter_p90_s,iter_p99_s,wasted_results,wasted_bytes,wasted_compute_s,\
             front_p50_s,front_p99_s,tail_learner,tail_p99_s,injected_share"
        ));
        let row = text.lines().nth(1).unwrap();
        assert!(row.contains(",2,100,0.003000000,"), "waste columns: {row}");
        assert!(row.contains(",5,0.040000000,"), "tail learner + p99: {row}");
        // 5 × 0.012 → the exact-below-5-samples quantile path
        assert!(row.contains("0.012000000,0.012000000,0.012000000"), "quantiles: {row}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_json_is_valid_and_carries_cache_counters() {
        let cells = vec![cell(Scheme::Mds, 0), cell(Scheme::Ldpc, 4)];
        let dir = std::env::temp_dir().join("coded_marl_sweep_json_test");
        let path = dir.join("BENCH_sweep.json");
        write_bench_json(&cells, Duration::from_millis(250), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "sim_sweep");
        assert_eq!(json.get("decode_plan_hits").unwrap().as_usize().unwrap(), 8);
        assert_eq!(json.get("decode_plan_misses").unwrap().as_usize().unwrap(), 2);
        let rate = json.get("decode_plan_hit_rate").unwrap().as_f64().unwrap();
        assert!((rate - 0.8).abs() < 1e-9);
        let cells_json = json.get("cells").unwrap();
        assert_eq!(cells_json.as_arr().unwrap().len(), 2);
        // Observability keys ride on every cell, finite numbers only
        // (empty sketches serialize as 0, never NaN).
        let c0 = &cells_json.as_arr().unwrap()[0];
        assert!((c0.get("iter_p99_s").unwrap().as_f64().unwrap() - 0.012).abs() < 1e-12);
        assert_eq!(c0.get("wasted_results").unwrap().as_usize().unwrap(), 2);
        assert_eq!(c0.get("wasted_bytes").unwrap().as_usize().unwrap(), 100);
        assert_eq!(c0.get("tail_learner").unwrap().as_usize().unwrap(), 5);
        assert!((c0.get("tail_p99_s").unwrap().as_f64().unwrap() - 0.040).abs() < 1e-12);
        assert_eq!(c0.get("injected_share").unwrap().as_f64().unwrap(), 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn render_table_dedups_duplicate_cells_first_wins() {
        let mut dup = cell(Scheme::Mds, 2);
        dup.redundancy = 99.0;
        let cells = vec![cell(Scheme::Mds, 2), dup];
        let txt = render_table(&cells, &[2]);
        assert!(txt.contains("2.5x"), "first cell's info must win:\n{txt}");
        assert!(!txt.contains("99.0x"), "duplicate must not overwrite:\n{txt}");
    }

    // --- SweepAxis::resolve: the axis-priority + conflict table ---

    #[test]
    fn axis_resolution_priority_and_defaults() {
        let cfg = base();
        assert_eq!(SweepAxis::resolve(&cfg, false, false).unwrap(), SweepAxis::Grid);
        assert_eq!(SweepAxis::resolve(&cfg, true, false).unwrap(), SweepAxis::Bandwidth);
        let mut c = base();
        c.adaptive = true;
        assert_eq!(SweepAxis::resolve(&c, false, false).unwrap(), SweepAxis::Adaptive);
        let mut c = base();
        c.fault.crash_rate = 0.1;
        assert_eq!(SweepAxis::resolve(&c, false, false).unwrap(), SweepAxis::Fault);
        // Corruption outranks fault rather than conflicting with it:
        // the byzantine cell records both counter sets.
        c.corrupt.rate = 0.1;
        assert_eq!(SweepAxis::resolve(&c, false, false).unwrap(), SweepAxis::Byzantine);
        assert_eq!(SweepAxis::resolve(&cfg, false, true).unwrap(), SweepAxis::Pipeline);
    }

    #[test]
    fn axis_resolution_rejects_every_conflicting_pair() {
        let corrupt = || {
            let mut c = base();
            c.corrupt.rate = 0.5;
            c
        };
        let fault = || {
            let mut c = base();
            c.fault.omission_rate = 0.5;
            c
        };
        let adaptive = || {
            let mut c = base();
            c.adaptive = true;
            c
        };
        let traced = || {
            let mut c = base();
            c.trace = Some("t.jsonl".into());
            c
        };
        // byzantine × {bandwidth-list, adaptive}
        assert!(SweepAxis::resolve(&corrupt(), true, false).is_err());
        let mut c = corrupt();
        c.adaptive = true;
        assert!(SweepAxis::resolve(&c, false, false).is_err());
        // fault × {bandwidth-list, adaptive}
        assert!(SweepAxis::resolve(&fault(), true, false).is_err());
        let mut c = fault();
        c.adaptive = true;
        assert!(SweepAxis::resolve(&c, false, false).is_err());
        // adaptive × bandwidth-list
        assert!(SweepAxis::resolve(&adaptive(), true, false).is_err());
        // pipeline × every other axis
        assert!(SweepAxis::resolve(&corrupt(), false, true).is_err());
        assert!(SweepAxis::resolve(&fault(), false, true).is_err());
        assert!(SweepAxis::resolve(&adaptive(), false, true).is_err());
        assert!(SweepAxis::resolve(&base(), true, true).is_err());
        assert!(SweepAxis::resolve(&traced(), false, true).is_err());
    }

    // --- Pipeline axis ---

    /// The pipeline axis end to end at test scale: a flat base yields
    /// exactly the depth-{1,2} pair, depth 2 is never slower once the
    /// prelude has nonzero cost (and strictly faster here, because the
    /// collect+decode window absorbs part of it), and
    /// BENCH_pipeline.json is valid JSON carrying the overlap rows.
    #[test]
    fn pipeline_sweep_runs_and_writes_json() {
        let mut b = base();
        b.ctrl_compute = Duration::from_millis(5);
        let cfg = SweepConfig {
            base: b.clone(),
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Mds, Scheme::Uncoded],
            ks: vec![0, 2],
            delay: Duration::from_millis(2),
            artifacts_dir: "artifacts".into(),
        };
        let points = run_pipeline_sweep(&cfg).unwrap();
        assert_eq!(points.len(), 2, "flat base → depth {{1,2}} only");
        assert!(points.iter().all(|p| p.topology == Topology::Flat));
        let rows = pipeline_overlap(&points);
        assert_eq!(rows.len(), 2, "one row per scheme");
        for r in &rows {
            assert!(r.depth1_mean_s > 0.0 && r.depth2_mean_s > 0.0);
            assert!(
                r.overlap_ratio > 1.0,
                "depth 2 must overlap the 5 ms prelude: {} {:.6}",
                r.scheme,
                r.overlap_ratio
            );
        }
        let txt = pipeline_table(&points);
        assert!(txt.contains("overlap") && txt.contains("flat"), "{txt}");

        let dir = std::env::temp_dir().join("coded_marl_pipeline_json_test");
        let path = dir.join("BENCH_pipeline.json");
        write_pipeline_json(&points, &b, Duration::from_millis(9), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "pipeline_sweep");
        assert_eq!(json.get("ctrl_compute_us").unwrap().as_usize().unwrap(), 5000);
        assert_eq!(json.get("points").unwrap().as_arr().unwrap().len(), 2);
        let overlap = json.get("overlap").unwrap();
        assert_eq!(overlap.as_arr().unwrap().len(), 2);
        for r in overlap.as_arr().unwrap() {
            assert!(r.get("overlap_ratio").unwrap().as_f64().unwrap() > 1.0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A racked base adds the racked twin pair, and incast queueing
    /// makes the racked cells strictly slower than their flat twins.
    #[test]
    fn pipeline_sweep_racked_base_adds_racked_points() {
        let mut b = base();
        b.ctrl_compute = Duration::from_millis(1);
        b.topology = Topology::Racks { racks: 2, width: 4 };
        b.uplink_mbps = 1.0;
        let cfg = SweepConfig {
            base: b,
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Mds],
            ks: vec![0],
            delay: Duration::ZERO,
            artifacts_dir: "artifacts".into(),
        };
        let points = run_pipeline_sweep(&cfg).unwrap();
        assert_eq!(points.len(), 4, "flat pair + racked pair");
        assert_eq!(points[0].topology, Topology::Flat);
        assert_eq!(points[0].uplink_mbps, 0.0, "flat twins run the free network");
        assert_eq!(points[2].topology, Topology::Racks { racks: 2, width: 4 });
        let rows = pipeline_overlap(&points);
        assert_eq!(rows.len(), 2, "one scheme × two topologies");
        let flat = rows.iter().find(|r| r.topology == Topology::Flat).unwrap();
        let racked = rows.iter().find(|r| r.topology != Topology::Flat).unwrap();
        assert!(
            racked.depth1_mean_s > flat.depth1_mean_s,
            "1 MB/s uplinks must serialize the result incast: racked {:.6}s vs flat {:.6}s",
            racked.depth1_mean_s,
            flat.depth1_mean_s
        );
        for r in &rows {
            assert!(
                r.overlap_ratio >= 1.0 - 1e-9,
                "depth 2 never slower: {} {:.6}",
                r.topology.label(),
                r.overlap_ratio
            );
        }
    }

    #[test]
    fn ks_for_n_rounds_clamps_and_dedups() {
        assert_eq!(ks_for_n(&[0.0, 0.3, 1.0], 7), vec![0, 2, 7]);
        assert_eq!(ks_for_n(&[0.0, 0.05, 0.5], 9), vec![0, 5], "0.05·9 rounds to 0, deduped");
        assert_eq!(ks_for_n(&[2.0], 4), vec![4], "clamped to N");
        assert_eq!(ks_for_n(&[0.0, 0.05, 0.25], 1000), vec![0, 50, 250]);
    }

    /// The scale-study grid end to end at test scale: every (dist, N)
    /// point carries a full schemes × k cell set, the crossover table
    /// renders, and BENCH_scale.json is valid JSON with the exact sums.
    #[test]
    fn scale_study_runs_grid_and_writes_json() {
        let mut study_base = base();
        // a Pareto tail draw may exceed the 120 s real-time default;
        // virtual seconds are free
        study_base.collect_timeout = Duration::from_secs(24 * 3600);
        let cfg = ScaleStudyConfig {
            base: study_base,
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Mds, Scheme::Ldpc],
            ns: vec![7, 9],
            k_fracs: vec![0.0, 0.3, 1.0],
            delay: Duration::from_millis(40),
            dists: vec![DelayDist::Fixed, DelayDist::Pareto { alpha: 1.5 }],
            artifacts_dir: "artifacts".into(),
        };
        let points = run_scale_study(&cfg).unwrap();
        assert_eq!(points.len(), 4, "2 dists × 2 Ns");
        assert_eq!(points[0].ks, vec![0, 2, 7]);
        assert_eq!(points[1].ks, vec![0, 3, 9]);
        for p in &points {
            assert_eq!(p.cells.len(), 2 * p.ks.len(), "schemes × ks");
            for c in &p.cells {
                assert_eq!(c.measured_iters, 3);
                assert!(c.total >= c.wait, "{}/{}", c.scheme, c.k);
            }
        }
        let txt = crossover_summary(&points);
        assert!(txt.contains("ldpc/mds") && txt.contains("pareto"), "{txt}");

        let dir = std::env::temp_dir().join("coded_marl_scale_json_test");
        let path = dir.join("BENCH_scale.json");
        write_scale_json(&points, Duration::from_millis(80), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "scale_study");
        let pts = json.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 4);
        assert!(pts.iter().any(|p| p.get("dist").unwrap().as_str().unwrap() == "pareto"));
        assert_eq!(pts[0].get("cells").unwrap().as_arr().unwrap().len(), 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The once-dead `Stats::merge` path, now wired: grid-level
    /// per-iteration statistics are per-cell [`Stats`] merged across
    /// cells — identical to one sequential accumulator.
    #[test]
    fn grid_iter_stats_merges_cells_exactly() {
        let mut a = cell(Scheme::Mds, 0);
        a.iter_stats = Stats::new();
        for x in [0.010, 0.014, 0.012] {
            a.iter_stats.push(x);
        }
        let mut b = cell(Scheme::Ldpc, 2);
        b.iter_stats = Stats::new();
        for x in [0.030, 0.050] {
            b.iter_stats.push(x);
        }
        let merged = grid_iter_stats(&[a, b]);
        let mut seq = Stats::new();
        for x in [0.010, 0.014, 0.012, 0.030, 0.050] {
            seq.push(x);
        }
        assert_eq!(merged.count(), seq.count());
        assert!((merged.mean() - seq.mean()).abs() < 1e-12);
        assert!((merged.variance() - seq.variance()).abs() < 1e-12);
        assert_eq!(merged.min(), 0.010);
        assert_eq!(merged.max(), 0.050);
        // a real sweep populates the per-cell stats from its log
        let cells = run_sweep(&SweepConfig {
            base: base(),
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Mds],
            ks: vec![0],
            delay: Duration::ZERO,
            artifacts_dir: "artifacts".into(),
        })
        .unwrap();
        assert_eq!(cells[0].iter_stats.count(), 3, "one sample per measured iteration");
        let want = cells[0].total.as_secs_f64() / 3.0;
        assert!((grid_iter_stats(&cells).mean() - want).abs() < 1e-9);
    }

    /// The bandwidth axis: a finite-bandwidth point must be slower
    /// than the infinite-bandwidth point of the same grid, record
    /// nonzero transfer legs, and BENCH_model.json must parse with the
    /// per-cell network fields.
    #[test]
    fn bandwidth_sweep_charges_transfer_and_writes_model_json() {
        let sweep = SweepConfig {
            base: base(),
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Uncoded, Scheme::Mds],
            ks: vec![0],
            delay: Duration::ZERO,
            artifacts_dir: "artifacts".into(),
        };
        // 0 = infinite; 0.5 MB/s makes the ~KB-scale synthetic bodies
        // clearly visible in virtual time.
        let points = run_bandwidth_sweep(&sweep, &[0.0, 0.5]).unwrap();
        assert_eq!(points.len(), 2);
        let (free, slow) = (&points[0], &points[1]);
        for (f, s) in free.cells.iter().zip(slow.cells.iter()) {
            assert_eq!(f.net, NetStats::default(), "infinite bandwidth must charge nothing");
            assert!(s.net.broadcast_ns > 0, "{}/{}: broadcast leg must be charged", s.scheme, s.k);
            assert!(s.net.return_ns > 0, "{}/{}: return leg must be charged", s.scheme, s.k);
            assert_eq!(s.net.bodies as usize, s.measured_iters, "one body per broadcast");
            assert!(
                s.mean_iter > f.mean_iter,
                "{}/{}: finite bandwidth must cost time ({:?} vs {:?})",
                s.scheme,
                s.k,
                s.mean_iter,
                f.mean_iter
            );
        }
        let table = bandwidth_table(&points);
        assert!(table.contains("bw=inf") && table.contains("bw=0.5MB/s"), "{table}");

        let dir = std::env::temp_dir().join("coded_marl_model_json_test");
        let path = dir.join("BENCH_model.json");
        write_model_json(&points, &sweep.base, Duration::from_millis(5), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "model_sweep");
        assert_eq!(json.get("compute_model").unwrap().as_str().unwrap(), "fixed");
        assert!(json.get("iter_mean_s").unwrap().as_f64().unwrap() > 0.0);
        let pts = json.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
        let slow_cells = pts[1].get("cells").unwrap().as_arr().unwrap();
        for c in slow_cells {
            assert!(c.get("net_broadcast_per_iter_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(c.get("net_return_per_iter_s").unwrap().as_f64().unwrap() > 0.0);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn derive_scheme_seed_is_stable_and_spread() {
        assert_eq!(
            derive_scheme_seed(9, Scheme::Mds),
            derive_scheme_seed(9, Scheme::Mds)
        );
        assert_ne!(
            derive_scheme_seed(9, Scheme::Mds),
            derive_scheme_seed(9, Scheme::Ldpc)
        );
        assert_ne!(
            derive_scheme_seed(9, Scheme::Mds),
            derive_scheme_seed(10, Scheme::Mds)
        );
    }

    /// A (seed, scheme) cell must not depend on which other schemes are
    /// in the sweep — single-scheme repros of full-grid anomalies have
    /// to measure the identical experiment.
    #[test]
    fn scheme_cells_are_independent_of_the_sweep_list() {
        let run = |schemes: Vec<Scheme>| {
            let cfg = SweepConfig {
                base: base(),
                spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
                schemes,
                ks: vec![2],
                delay: Duration::from_millis(40),
                artifacts_dir: "artifacts".into(),
            };
            run_sweep(&cfg).unwrap()
        };
        let full = run(vec![Scheme::Uncoded, Scheme::Mds, Scheme::Ldpc]);
        let solo = run(vec![Scheme::Mds]);
        let full_mds = full.iter().find(|c| c.scheme == Scheme::Mds).unwrap();
        assert_eq!(full_mds.mean_iter, solo[0].mean_iter);
        assert_eq!(full_mds.mean_wait, solo[0].mean_wait);
        assert_eq!(full_mds.redundancy.to_bits(), solo[0].redundancy.to_bits());
    }

    /// The tentpole determinism contract: the shard pool produces
    /// bit-identical cells to the serial runner, at any thread count.
    #[test]
    fn parallel_sweep_matches_serial_at_any_thread_count() {
        let sweep = |threads: usize| {
            let mut base = base();
            base.sweep_threads = threads;
            let cfg = SweepConfig {
                base,
                spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
                schemes: vec![Scheme::Uncoded, Scheme::Mds, Scheme::Ldpc],
                ks: vec![0, 3],
                delay: Duration::from_millis(40),
                artifacts_dir: "artifacts".into(),
            };
            run_sweep(&cfg).unwrap()
        };
        let serial = sweep(1);
        for threads in [2usize, 4, 7] {
            let parallel = sweep(threads);
            assert_eq!(serial.len(), parallel.len());
            for (a, b) in serial.iter().zip(parallel.iter()) {
                assert_eq!(a.scheme, b.scheme, "threads={threads}");
                assert_eq!(a.k, b.k, "threads={threads}");
                assert_eq!(a.mean_iter, b.mean_iter, "threads={threads} {}/{}", a.scheme, a.k);
                assert_eq!(a.mean_wait, b.mean_wait, "threads={threads} {}/{}", a.scheme, a.k);
                assert_eq!(a.measured_iters, b.measured_iters, "threads={threads}");
                assert_eq!(a.redundancy.to_bits(), b.redundancy.to_bits(), "threads={threads}");
                assert_eq!(a.tolerance, b.tolerance, "threads={threads}");
                assert_eq!(
                    (a.decode_plan.hits, a.decode_plan.misses),
                    (b.decode_plan.hits, b.decode_plan.misses),
                    "threads={threads} {}/{}",
                    a.scheme,
                    a.k
                );
            }
        }
    }

    /// Real-time sweeps must not shard (wall-clock cells would contend);
    /// the width helper enforces it regardless of the knob.
    #[test]
    fn real_time_sweeps_run_serially() {
        let mut base = base();
        base.time_mode = TimeMode::Real;
        base.sweep_threads = 8;
        let cfg = SweepConfig {
            base,
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Mds],
            ks: vec![0],
            delay: Duration::ZERO,
            artifacts_dir: "artifacts".into(),
        };
        assert_eq!(shard_width(&cfg, 5), 1);
        let mut virt = cfg;
        virt.base.time_mode = TimeMode::Virtual;
        assert_eq!(shard_width(&virt, 5), 5, "threads cap at the job count");
        virt.base.sweep_threads = 3;
        assert_eq!(shard_width(&virt, 5), 3);
    }

    /// The fault axis end to end: crash-everyone cells terminate
    /// deterministically through the degraded path (never a hang to
    /// the 24 h virtual collect window), zero-fault cells survive every
    /// iteration, and BENCH_fault.json parses with the survival keys.
    #[test]
    fn fault_sweep_records_survival_and_writes_fault_json() {
        use crate::config::FaultConfig;
        let mut fault_base = base();
        fault_base.collect_timeout = Duration::from_secs(24 * 3600);
        // crash_rate = 1 kills every learner on the first coded
        // iteration: survivors < M, so every scheme stops via
        // FaultError — degraded-stop, not a timeout.
        fault_base.fault = FaultConfig { crash_rate: 1.0, ..FaultConfig::none() };
        let sweep = SweepConfig {
            base: fault_base,
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Uncoded, Scheme::Mds],
            ks: vec![0],
            delay: Duration::ZERO,
            artifacts_dir: "artifacts".into(),
        };
        let wall_t = std::time::Instant::now();
        let cells = run_fault_sweep(&sweep).unwrap();
        assert!(
            wall_t.elapsed() < Duration::from_secs(60),
            "a dead fleet must fail fast, not idle out the virtual window"
        );
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert!(!c.survived, "{}: no scheme survives losing everyone", c.scheme);
            assert!(c.error.as_deref().unwrap_or("").contains("cannot reach rank M"));
            assert!(c.iters_done < c.iters_target);
            assert!(c.availability < 1.0);
            assert!(c.stats.degraded_iters > 0, "{}: the degraded path must fire", c.scheme);
        }

        let txt = fault_table(&cells);
        assert!(txt.contains("degraded-stop") && txt.contains("availability"), "{txt}");
        let dir = std::env::temp_dir().join("coded_marl_fault_json_test");
        let path = dir.join("BENCH_fault.json");
        write_fault_json(&cells, &sweep.base, Duration::from_millis(9), &path).unwrap();

        // A fault-free base survives everything, with zeroed counters.
        let mut clean = sweep;
        clean.base.fault = FaultConfig::none();
        let clean_cells = run_fault_sweep(&clean).unwrap();
        for c in &clean_cells {
            assert!(c.survived && c.availability == 1.0, "{}", c.scheme);
            assert_eq!(c.stats, FaultStats::default(), "{}", c.scheme);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "fault_sweep");
        let jcells = json.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(jcells.len(), 2);
        for c in jcells {
            assert!(c.get("availability").unwrap().as_f64().unwrap() < 1.0);
            assert!(c.get("iters_done").unwrap().as_usize().is_ok());
            assert!(c.get("recovery_s").unwrap().as_f64().is_ok());
            assert!(c.get("error").unwrap().as_str().is_ok());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Transient crash-and-restart within tolerance: MDS at N=7, M=2
    /// (tolerance N−M = 5) rides out restarting learners and finishes
    /// every iteration, while the losses are corroborated (not silent).
    #[test]
    fn fault_sweep_survives_transient_crashes_within_tolerance() {
        use crate::config::FaultConfig;
        let mut fault_base = base();
        fault_base.collect_timeout = Duration::from_secs(24 * 3600);
        fault_base.iterations = 7; // 6 measured + warmup: room to recover
        fault_base.fault = FaultConfig {
            crash_rate: 0.15,
            crash_restart: Some(Duration::from_millis(1)),
            ..FaultConfig::none()
        };
        let sweep = SweepConfig {
            base: fault_base,
            spec: RunSpec::synthetic(EnvKind::CoopNav, 2, 0, 8, 4),
            schemes: vec![Scheme::Mds],
            ks: vec![0],
            delay: Duration::ZERO,
            artifacts_dir: "artifacts".into(),
        };
        let cells = run_fault_sweep(&sweep).unwrap();
        let c = &cells[0];
        assert!(c.survived, "MDS must mask transient crashes: {:?}", c.error);
        assert_eq!(c.availability, 1.0);
        assert_eq!(c.iters_done, c.iters_target);
        assert!(c.stats.lost_results > 0, "crashes must be corroborated as losses");
    }

    /// The byzantine axis end to end: with corruption injected, the
    /// MDS cell's verified decoder sees and detects it; with the rate
    /// at zero, every counter stays zero and every cell survives; and
    /// BENCH_byzantine.json parses with the detection keys the CI
    /// smoke gate asserts on.
    #[test]
    fn byzantine_sweep_detects_injected_corruption_and_writes_json() {
        use crate::config::{CorruptConfig, CorruptMode};
        let mut byz_base = base();
        byz_base.iterations = 7; // 6 measured + warmup: several injections
        byz_base.corrupt = CorruptConfig { rate: 0.25, mode: CorruptMode::Adversarial };
        let sweep = SweepConfig {
            base: byz_base,
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Mds, Scheme::Replication],
            ks: vec![0],
            delay: Duration::ZERO,
            artifacts_dir: "artifacts".into(),
        };
        let cells = run_byzantine_sweep(&sweep).unwrap();
        assert_eq!(cells.len(), 2);
        let mds = &cells[0];
        assert_eq!(mds.scheme, Scheme::Mds);
        assert_eq!(mds.tolerance, 3, "MDS at N=7, M=4");
        assert_eq!(mds.correction_budget, 1);
        assert!(mds.byz.corrupted_seen > 0, "rate 0.25 over 7 iters must inject");
        assert!(
            mds.byz.detected > 0,
            "the residual check must fire on adversarial rows: {:?}",
            mds.byz
        );
        assert!(
            mds.byz.verify_failures > 0 && mds.byz.surplus_rows > 0,
            "verify mode must collect surplus and spend it: {:?}",
            mds.byz
        );

        let txt = byzantine_table(&cells);
        assert!(txt.contains("mds") && txt.contains("quarantined"), "{txt}");

        let dir = std::env::temp_dir().join("coded_marl_byzantine_json_test");
        let path = dir.join("BENCH_byzantine.json");
        write_byzantine_json(&cells, &sweep.base, Duration::from_millis(9), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "byzantine_sweep");
        assert_eq!(json.get("corrupt_mode").unwrap().as_str().unwrap(), "adversarial");
        let jcells = json.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(jcells.len(), 2);
        for c in jcells {
            assert!(c.get("corrupted_seen").unwrap().as_usize().is_ok());
            assert!(c.get("detected").unwrap().as_usize().is_ok());
            assert!(c.get("quarantined").unwrap().as_usize().is_ok());
            assert!(c.get("correction_budget").unwrap().as_usize().is_ok());
        }
        std::fs::remove_dir_all(&dir).ok();

        // Corruption-free base: verification runs but every Byzantine
        // counter stays zero and every scheme survives untouched.
        let mut clean = sweep;
        clean.base.corrupt = CorruptConfig::none();
        let clean_cells = run_byzantine_sweep(&clean).unwrap();
        for c in &clean_cells {
            assert!(c.survived, "{}: clean cells must survive", c.scheme);
            assert_eq!(c.iters_done, c.iters_target, "{}", c.scheme);
            let b = c.byz;
            assert_eq!(
                (b.corrupted_seen, b.verify_failures, b.detected, b.identified, b.quarantined),
                (0, 0, 0, 0, 0),
                "{}: clean run must not trip the checker: {b:?}",
                c.scheme
            );
        }
    }

    /// The adaptive axis end to end on a hot measured trace: a run
    /// provisioned with the uncoded scheme (tolerance 0) sees three
    /// learners straggle 120 ms every round, so the obs-driven
    /// selector must switch to a coded plan; the results encoded under
    /// the abandoned plan are counted as waste (never decoded); and
    /// BENCH_adaptive.json parses with the switch keys the CI smoke
    /// gate asserts on.
    #[test]
    fn adaptive_sweep_switches_off_a_mis_provisioned_scheme_and_writes_json() {
        let dir = std::env::temp_dir().join("coded_marl_adaptive_sweep_test");
        std::fs::create_dir_all(&dir).unwrap();
        // A measured trace with a persistent hot set: columns 0-2 take
        // 120 ms every round (the uncoded scheme's active learners),
        // the rest are instant. Uniform across rounds, so the
        // seed-offset replay cursor cannot change the regime.
        let trace_path = dir.join("hot.csv");
        let mut csv = String::from("t_s,l0,l1,l2,l3,l4,l5,l6\n");
        for r in 0..8 {
            csv.push_str(&format!("{}.0,120,120,120,0,0,0,0\n", r));
        }
        std::fs::write(&trace_path, csv).unwrap();

        let mut adaptive_base = sweep_base("synthetic", 7, 12, Duration::from_millis(2), 9);
        adaptive_base.episode_len = 5;
        adaptive_base.trace = Some(trace_path);
        let sweep = SweepConfig {
            base: adaptive_base,
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Uncoded],
            ks: vec![0],
            delay: Duration::ZERO,
            artifacts_dir: "artifacts".into(),
        };
        let cells = run_adaptive_sweep(&sweep).unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.start_scheme, Scheme::Uncoded);
        assert!(
            c.final_epoch >= 1,
            "the selector must install at least one new plan on a hot trace"
        );
        assert_ne!(
            c.final_scheme,
            Scheme::Uncoded,
            "tolerance-0 provisioning must not survive 3 persistent stragglers"
        );
        assert_eq!(c.measured_iters, 12);

        let txt = adaptive_table(&cells);
        assert!(txt.contains("uncoded") && txt.contains("switches"), "{txt}");

        let path = dir.join("BENCH_adaptive.json");
        write_adaptive_json(&cells, &sweep.base, Duration::from_millis(7), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = crate::runtime::json::Json::parse(&text).expect("valid JSON");
        assert_eq!(json.get("bench").unwrap().as_str().unwrap(), "adaptive_sweep");
        assert_eq!(json.get("adapt_min_obs").unwrap().as_usize().unwrap(), 5);
        let jcells = json.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(jcells.len(), 1);
        let jc = &jcells[0];
        assert_eq!(jc.get("start_scheme").unwrap().as_str().unwrap(), "uncoded");
        assert!(jc.get("plan_switches").unwrap().as_usize().unwrap() >= 1);
        assert_ne!(jc.get("final_scheme").unwrap().as_str().unwrap(), "uncoded");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite determinism pin: the ordinary sweep grid with the
    /// adaptive selector live in every cell stays bit-identical between
    /// the serial runner and the shard pool at any thread count — the
    /// selector decides from its own seeded stream, never from
    /// scheduling.
    #[test]
    fn adaptive_grid_is_bit_identical_across_sweep_threads() {
        let sweep = |threads: usize| {
            // enough measured iterations (10) that the selector clears
            // its min-observation gate and can actually switch
            let mut base = sweep_base("synthetic", 7, 10, Duration::from_millis(2), 9);
            base.episode_len = 5;
            base.adaptive = true;
            base.sweep_threads = threads;
            let cfg = SweepConfig {
                base,
                spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
                schemes: vec![Scheme::Uncoded, Scheme::Mds, Scheme::Ldpc],
                ks: vec![0, 3],
                delay: Duration::from_millis(40),
                artifacts_dir: "artifacts".into(),
            };
            run_sweep(&cfg).unwrap()
        };
        let serial = sweep(1);
        for threads in [2usize, 4] {
            let parallel = sweep(threads);
            assert_eq!(serial.len(), parallel.len());
            for (a, b) in serial.iter().zip(parallel.iter()) {
                assert_eq!(a.scheme, b.scheme, "threads={threads}");
                assert_eq!(a.k, b.k, "threads={threads}");
                assert_eq!(a.total, b.total, "threads={threads} {}/{}", a.scheme, a.k);
                assert_eq!(a.wait, b.wait, "threads={threads} {}/{}", a.scheme, a.k);
                assert_eq!(
                    (a.waste.results, a.waste.bytes, a.waste.compute_ns),
                    (b.waste.results, b.waste.bytes, b.waste.compute_ns),
                    "threads={threads} {}/{}",
                    a.scheme,
                    a.k
                );
            }
        }
    }
}
