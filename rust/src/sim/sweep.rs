//! Straggler-sweep runner shared by the `coded-marl sim-sweep`
//! subcommand, `examples/straggler_sweep.rs`, and the ablation bench:
//! one short training run per (scheme, straggler count) cell, mean
//! per-iteration time over the non-warmup iterations.
//!
//! The runner is time-mode agnostic — it builds pools through
//! [`crate::coordinator::spawn_pool`], so `base.time_mode` decides
//! whether a cell costs real wall-clock (threads + sleeps) or virtual
//! nanoseconds (discrete events). Under `TimeMode::Virtual` a full
//! 5-scheme × 5-k grid with the paper's t_s = 250 ms finishes in well
//! under a second.

use std::io::Write;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coding::Scheme;
use crate::config::{Backend, TimeMode, TrainConfig};
use crate::coordinator::{backend_factory, spawn_pool, Controller, RunSpec};
use crate::metrics::table::Table;
use crate::metrics::RunLog;

/// A sweep grid: the cross product of `schemes` × `ks`, run on top of
/// `base` (whose `scheme`/`straggler.k`/`straggler.delay` are
/// overwritten per cell).
pub struct SweepConfig {
    pub base: TrainConfig,
    pub spec: RunSpec,
    pub schemes: Vec<Scheme>,
    pub ks: Vec<usize>,
    /// Injected delay t_s applied to every cell with k > 0.
    pub delay: Duration,
    /// AOT artifacts directory, used only when `base.backend` is PJRT
    /// (mock sweeps never read it).
    pub artifacts_dir: std::path::PathBuf,
}

/// The baseline sweep cell config shared by the `sim-sweep` subcommand
/// and `examples/straggler_sweep.rs`: mock backend in virtual time,
/// one 25-step episode per iteration, and one warmup iteration on top
/// of `iterations` measured ones. Callers tweak the returned config
/// (e.g. `time_mode = Real` for a wall-clock reference run).
pub fn sweep_base(
    preset: impl Into<String>,
    n_learners: usize,
    iterations: usize,
    mock_compute: Duration,
    seed: u64,
) -> TrainConfig {
    let mut cfg = TrainConfig::new(preset);
    cfg.backend = Backend::Mock;
    cfg.time_mode = TimeMode::Virtual;
    cfg.n_learners = n_learners;
    cfg.iterations = iterations + 1; // +1 warmup
    cfg.episodes_per_iter = 1;
    cfg.episode_len = 25;
    cfg.warmup_iters = 1;
    cfg.mock_compute = mock_compute;
    cfg.seed = seed;
    cfg
}

/// Total simulated training time across cells (mean × measured
/// iterations) — the "how much time did the sweep model" headline.
pub fn simulated_total(cells: &[SweepCell]) -> Duration {
    cells.iter().map(|c| c.mean_iter * c.measured_iters as u32).sum()
}

/// One (scheme, k) cell's outcome.
pub struct SweepCell {
    pub scheme: Scheme,
    pub k: usize,
    /// Mean per-iteration training time over non-warmup iterations —
    /// the y-axis of the paper's Figs. 4-5.
    pub mean_iter: Duration,
    /// Mean of the collect/wait phase alone.
    pub mean_wait: Duration,
    /// Iterations averaged over (excludes warmup).
    pub measured_iters: usize,
    /// The scheme's compute redundancy (total agent-updates / M).
    pub redundancy: f64,
    /// Worst-case straggler tolerance of the assignment matrix.
    pub tolerance: usize,
}

/// Mean (total, wait) over the non-warmup iterations of a run log.
pub fn mean_non_warmup(log: &RunLog) -> (Duration, Duration, usize) {
    let mut total = Duration::ZERO;
    let mut wait = Duration::ZERO;
    let mut n = 0usize;
    for r in log.records.iter().filter(|r| r.decode_method != "warmup") {
        total += r.timing.total;
        wait += r.timing.wait;
        n += 1;
    }
    if n == 0 {
        return (Duration::ZERO, Duration::ZERO, 0);
    }
    (total / n as u32, wait / n as u32, n)
}

/// Run the grid cell by cell; cells are independent short trainings
/// (fresh pool, fresh controller) so a sweep is embarrassingly simple
/// to reason about and deterministic per cell.
pub fn run_sweep(sweep: &SweepConfig) -> Result<Vec<SweepCell>> {
    let mut cells = Vec::with_capacity(sweep.schemes.len() * sweep.ks.len());
    for &scheme in &sweep.schemes {
        for &k in &sweep.ks {
            let mut cfg = sweep.base.clone();
            cfg.scheme = scheme;
            cfg.straggler.k = k;
            cfg.straggler.delay = sweep.delay;
            let factory = backend_factory(&cfg, sweep.artifacts_dir.clone(), &sweep.spec);
            let pool = spawn_pool(&cfg, factory)?;
            let mut ctrl = Controller::new(cfg, sweep.spec.clone(), pool)
                .with_context(|| format!("building controller for {scheme} k={k}"))?;
            ctrl.train().with_context(|| format!("training cell {scheme} k={k}"))?;
            let (mean_iter, mean_wait, measured_iters) = mean_non_warmup(&ctrl.log);
            let redundancy = ctrl.code().redundancy();
            let tolerance = ctrl.code().worst_case_tolerance();
            ctrl.shutdown();
            cells.push(SweepCell {
                scheme,
                k,
                mean_iter,
                mean_wait,
                measured_iters,
                redundancy,
                tolerance,
            });
        }
    }
    Ok(cells)
}

/// Render the sweep as the schemes × k table the examples print
/// (cells in ms, plus the scheme's redundancy and tolerance).
pub fn render_table(cells: &[SweepCell], ks: &[usize]) -> String {
    let mut headers: Vec<String> = vec!["scheme".into()];
    headers.extend(ks.iter().map(|k| format!("k={k}")));
    headers.push("redundancy".into());
    headers.push("tolerance".into());
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    let mut schemes: Vec<Scheme> = Vec::new();
    for c in cells {
        if !schemes.contains(&c.scheme) {
            schemes.push(c.scheme);
        }
    }
    for scheme in schemes {
        let mut row = vec![scheme.name().to_string()];
        let mut info: Option<(f64, usize)> = None;
        for &k in ks {
            match cells.iter().find(|c| c.scheme == scheme && c.k == k) {
                Some(c) => {
                    row.push(format!("{:.1}ms", c.mean_iter.as_secs_f64() * 1e3));
                    info = Some((c.redundancy, c.tolerance));
                }
                None => row.push("-".into()),
            }
        }
        let (red, tol) = info.unwrap_or((f64::NAN, 0));
        row.push(format!("{red:.1}x"));
        row.push(tol.to_string());
        table.row(&row);
    }
    table.render()
}

/// One CSV row per cell (`scheme,k,mean_iter_s,mean_wait_s,iters`).
pub fn write_csv(cells: &[SweepCell], path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "scheme,k,mean_iter_s,mean_wait_s,iters,redundancy,tolerance")?;
    for c in cells {
        writeln!(
            f,
            "{},{},{:.6},{:.6},{},{:.3},{}",
            c.scheme.name(),
            c.k,
            c.mean_iter.as_secs_f64(),
            c.mean_wait.as_secs_f64(),
            c.measured_iters,
            c.redundancy,
            c.tolerance,
        )?;
    }
    f.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::EnvKind;

    fn base() -> TrainConfig {
        let mut cfg = sweep_base("synthetic", 7, 3, Duration::from_millis(2), 9);
        cfg.episode_len = 5;
        cfg
    }

    #[test]
    fn sweep_base_sets_the_virtual_protocol() {
        let cfg = base();
        assert_eq!(cfg.time_mode, TimeMode::Virtual);
        assert_eq!(cfg.backend, Backend::Mock);
        assert_eq!(cfg.iterations, 4, "3 measured + 1 warmup");
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn sweep_covers_the_grid_and_orders_cells() {
        let sweep = SweepConfig {
            base: base(),
            spec: RunSpec::synthetic(EnvKind::CoopNav, 4, 0, 8, 4),
            schemes: vec![Scheme::Uncoded, Scheme::Mds],
            // k = 3 is within MDS's worst-case tolerance (N−M = 3) but
            // k = N hits every learner — both deterministic outcomes.
            ks: vec![3, 7],
            delay: Duration::from_millis(40),
            artifacts_dir: "artifacts".into(),
        };
        let cells = run_sweep(&sweep).unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].scheme, Scheme::Uncoded);
        assert_eq!(cells[0].k, 3);
        assert_eq!(cells[3].scheme, Scheme::Mds);
        assert_eq!(cells[3].k, 7);
        assert!(cells.iter().all(|c| c.measured_iters == 3));
        // k = N stalls every scheme for the full t_s…
        let unc_all = &cells[1];
        assert!(
            unc_all.mean_iter >= Duration::from_millis(40),
            "uncoded with all learners straggling must wait out t_s, got {:?}",
            unc_all.mean_iter
        );
        // …while MDS masks k ≤ N−M regardless of which learners are hit
        let mds_k3 = &cells[2];
        assert!(
            mds_k3.mean_iter < Duration::from_millis(40),
            "MDS must mask 3 stragglers, got {:?}",
            mds_k3.mean_iter
        );
        assert_eq!(mds_k3.tolerance, 3);
        let txt = render_table(&cells, &sweep.ks);
        assert!(txt.contains("uncoded") && txt.contains("mds"));
    }

    #[test]
    fn csv_roundtrip() {
        let cells = vec![SweepCell {
            scheme: Scheme::Mds,
            k: 2,
            mean_iter: Duration::from_millis(12),
            mean_wait: Duration::from_millis(9),
            measured_iters: 5,
            redundancy: 2.5,
            tolerance: 3,
        }];
        let dir = std::env::temp_dir().join("coded_marl_sweep_csv_test");
        let path = dir.join("sweep.csv");
        write_csv(&cells, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("mds,2,0.012"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
