//! Experiment configuration — everything runtime-tunable on the Rust
//! side (the build-time constants live in python/compile/presets.py and
//! arrive via artifacts/manifest.json).
//!
//! A [`TrainConfig`] fully determines a run: preset (env + M + model
//! dims), learner pool size N, coding scheme, decode method, straggler
//! model, rollout/training schedule, and seed. `TrainConfig::from_args`
//! parses the CLI surface shared by `coded-marl train`, the examples
//! and the benches.

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::coding::decoder::DecodeMethod;
use crate::coding::Scheme;

/// How learner compute is implemented.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Real MADDPG update: each learner thread compiles and executes
    /// the AOT artifacts through PJRT (the production path).
    Pjrt,
    /// Deterministic synthetic update with configurable compute time —
    /// used by coordination tests/benches that isolate timing behaviour
    /// from XLA compute (DESIGN.md §2).
    Mock,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt => "pjrt",
            Backend::Mock => "mock",
        }
    }

    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "pjrt" => Some(Backend::Pjrt),
            "mock" => Some(Backend::Mock),
            _ => None,
        }
    }
}

/// Which transport connects controller and learners.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    /// Learner threads in the controller process (default).
    Local,
    /// Separate `coded-marl worker` processes over localhost TCP.
    Tcp,
}

impl Transport {
    pub fn name(&self) -> &'static str {
        match self {
            Transport::Local => "local",
            Transport::Tcp => "tcp",
        }
    }

    pub fn parse(s: &str) -> Option<Transport> {
        match s {
            "local" => Some(Transport::Local),
            "tcp" => Some(Transport::Tcp),
            _ => None,
        }
    }
}

/// Whether time is spent or simulated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeMode {
    /// Wall-clock execution: learner threads really compute and really
    /// sleep through injected delays (the paper's protocol).
    Real,
    /// Discrete-event execution on a [`crate::sim::VirtualClock`]:
    /// learner numerics run unchanged, but compute time and injected
    /// delays advance a virtual nanosecond counter instead of
    /// sleeping, so straggler sweeps run at hardware speed. Requires
    /// the local transport and the mock backend (compute is modeled
    /// from `mock_compute`, not executed through PJRT).
    Virtual,
}

impl TimeMode {
    pub fn name(&self) -> &'static str {
        match self {
            TimeMode::Real => "real",
            TimeMode::Virtual => "virtual",
        }
    }

    pub fn parse(s: &str) -> Option<TimeMode> {
        match s {
            "real" => Some(TimeMode::Real),
            "virtual" => Some(TimeMode::Virtual),
            _ => None,
        }
    }
}

/// How an injected straggler's delay is drawn each iteration. Every
/// non-fixed distribution is **mean-normalized to t_s**, so sweeps
/// over tails compare equal injected delay *budgets* and differ only
/// in how that budget concentrates in the tail.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DelayDist {
    /// Deterministic t_s — the paper's §V-C model.
    Fixed,
    /// `t_s · Exp(1)`: light exponential tail (the PR-0 ablation's
    /// `--straggler-exponential`, kept as an alias).
    Exponential,
    /// Pareto with shape `alpha` (must be > 1 for a finite mean),
    /// scaled to mean t_s: `x_m / U^{1/alpha}` with
    /// `x_m = t_s·(alpha−1)/alpha`. Power-law tail — the heavy-tail
    /// regime measured in cluster traces; `alpha < 2` has infinite
    /// variance.
    Pareto { alpha: f64 },
    /// Lognormal with shape `sigma` (> 0), scaled to mean t_s:
    /// `t_s · exp(sigma·Z − sigma²/2)`.
    LogNormal { sigma: f64 },
}

impl DelayDist {
    /// Default Pareto shape (`--delay-alpha`) — single source for every
    /// CLI surface that reads the knob.
    pub const DEFAULT_ALPHA: f64 = 1.5;
    /// Default lognormal shape (`--delay-sigma`).
    pub const DEFAULT_SIGMA: f64 = 1.0;

    pub fn name(&self) -> &'static str {
        match self {
            DelayDist::Fixed => "fixed",
            DelayDist::Exponential => "exponential",
            DelayDist::Pareto { .. } => "pareto",
            DelayDist::LogNormal { .. } => "lognormal",
        }
    }

    /// Parse a `--delay-dist` value; `alpha`/`sigma` are the shape
    /// knobs for the families that take one.
    pub fn parse(s: &str, alpha: f64, sigma: f64) -> Option<DelayDist> {
        match s {
            "fixed" => Some(DelayDist::Fixed),
            "exponential" | "exp" => Some(DelayDist::Exponential),
            "pareto" => Some(DelayDist::Pareto { alpha }),
            "lognormal" => Some(DelayDist::LogNormal { sigma }),
            _ => None,
        }
    }

    /// Short human label for run summaries.
    pub fn label(&self) -> String {
        match self {
            DelayDist::Fixed => "fixed".into(),
            DelayDist::Exponential => "exp".into(),
            DelayDist::Pareto { alpha } => format!("pareto(a={alpha})"),
            DelayDist::LogNormal { sigma } => format!("lognormal(s={sigma})"),
        }
    }
}

/// Physical layout of the learner fleet for the per-link network
/// model (`--topology`). The default **flat** topology is the PR 5
/// single-link model: every transfer shares one modeled bandwidth and
/// returns never queue. `racks:<r>x<w>` places learners round-robin
/// into `r` racks of `w` slots each; Result returns then serialize
/// over their rack's uplink (`--uplink-mbps`) and queue again on the
/// controller's ingress link (the base `--bandwidth`), so simultaneous
/// returns model incast instead of teleporting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// One shared link, no queueing — bit-identical to the PR 5 model.
    Flat,
    /// `racks` racks of `width` learners; learner j lives in rack
    /// `j / width`.
    Racks { racks: usize, width: usize },
}

impl Topology {
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Flat => "flat",
            Topology::Racks { .. } => "racks",
        }
    }

    /// Parse a `--topology` value: `flat` or `racks:<r>x<w>`.
    pub fn parse(s: &str) -> Option<Topology> {
        if s == "flat" {
            return Some(Topology::Flat);
        }
        let spec = s.strip_prefix("racks:")?;
        let (r, w) = spec.split_once('x')?;
        let racks: usize = r.parse().ok()?;
        let width: usize = w.parse().ok()?;
        Some(Topology::Racks { racks, width })
    }

    /// Short human label for run summaries.
    pub fn label(&self) -> String {
        match self {
            Topology::Flat => "flat".into(),
            Topology::Racks { racks, width } => format!("racks:{racks}x{width}"),
        }
    }

    /// Which rack learner `j` lives in (`None` under flat).
    pub fn rack_of(&self, learner: usize) -> Option<usize> {
        match self {
            Topology::Flat => None,
            Topology::Racks { width, .. } => Some(learner / width),
        }
    }

    /// Rack count (1 under flat — the whole fleet is one "rack").
    pub fn rack_count(&self) -> usize {
        match self {
            Topology::Flat => 1,
            Topology::Racks { racks, .. } => *racks,
        }
    }
}

/// Modeled network link for the virtual-time simulator
/// ([`crate::model::NetworkModel`]): per-message transfer time =
/// payload bytes / bandwidth + exponential jitter. The default is
/// **free** (infinite bandwidth, zero jitter) — bit-identical to the
/// pre-model sim.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetConfig {
    /// Link bandwidth in MB/s (1 MB = 10⁶ bytes); 0 = infinite.
    pub bandwidth_mbps: f64,
    /// Mean of the exponential per-message jitter; zero = none.
    pub jitter: std::time::Duration,
}

impl NetConfig {
    /// Infinite bandwidth, zero jitter: transfers cost nothing.
    pub fn free() -> NetConfig {
        NetConfig { bandwidth_mbps: 0.0, jitter: std::time::Duration::ZERO }
    }

    pub fn is_free(&self) -> bool {
        self.bandwidth_mbps == 0.0 && self.jitter.is_zero()
    }

    /// Short human label for run summaries.
    pub fn label(&self) -> String {
        if self.is_free() {
            return "free".into();
        }
        let bw = if self.bandwidth_mbps > 0.0 {
            format!("{}MB/s", self.bandwidth_mbps)
        } else {
            "inf".into()
        };
        if self.jitter.is_zero() {
            bw
        } else {
            format!("{bw}+j{:?}", self.jitter)
        }
    }
}

/// How per-update learner compute time is modeled in virtual time
/// ([`crate::model::ComputeModel`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ComputeModelCfg {
    /// Deterministic `mock_compute` per update (the PR 1 behavior).
    Fixed,
    /// Measured at pool startup: the backend's real per-update
    /// duration is timed and the sim samples the empirical
    /// distribution — works with any backend, which is what lifts the
    /// old `TimeMode::Virtual ⇒ Backend::Mock` restriction.
    Calibrated,
}

impl ComputeModelCfg {
    pub fn name(&self) -> &'static str {
        match self {
            ComputeModelCfg::Fixed => "fixed",
            ComputeModelCfg::Calibrated => "calibrated",
        }
    }

    pub fn parse(s: &str) -> Option<ComputeModelCfg> {
        match s {
            "fixed" => Some(ComputeModelCfg::Fixed),
            "calibrated" => Some(ComputeModelCfg::Calibrated),
            _ => None,
        }
    }
}

/// Straggler injection model (paper §V-C): each iteration, `k` learners
/// chosen uniformly at random delay their reply; the delay is `delay`
/// itself or a mean-`delay` draw from [`DelayDist`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StragglerConfig {
    /// Number of stragglers per iteration.
    pub k: usize,
    /// The injected delay t_s (the mean for non-fixed distributions).
    pub delay: std::time::Duration,
    /// Distribution the per-straggler delay is drawn from.
    pub dist: DelayDist,
}

impl StragglerConfig {
    pub fn none() -> StragglerConfig {
        StragglerConfig { k: 0, delay: std::time::Duration::ZERO, dist: DelayDist::Fixed }
    }

    pub fn fixed(k: usize, delay: std::time::Duration) -> StragglerConfig {
        StragglerConfig { k, delay, dist: DelayDist::Fixed }
    }
}

/// What the controller does when the surviving membership can no
/// longer reach rank M (crashes beyond the code's worst-case
/// tolerance mid-iteration, or fewer than M survivors overall).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradedMode {
    /// Terminate deterministically with a structured
    /// [`crate::coordinator::failure::FaultError`] naming the dead
    /// learners (the default — sweeps record the cell as degraded).
    Error,
    /// Force the currently-lost learners out of the membership, fall
    /// back to an uncoded assignment over the survivors, and retry the
    /// iteration — training continues as long as ≥ M learners survive.
    Uncoded,
}

impl DegradedMode {
    pub fn name(&self) -> &'static str {
        match self {
            DegradedMode::Error => "error",
            DegradedMode::Uncoded => "uncoded",
        }
    }

    pub fn parse(s: &str) -> Option<DegradedMode> {
        match s {
            "error" => Some(DegradedMode::Error),
            "uncoded" => Some(DegradedMode::Uncoded),
            _ => None,
        }
    }
}

/// Fault-injection and failure-handling knobs (`--crash-rate`,
/// `--crash-restart-s`, `--omission-rate`, `--degraded-mode`,
/// `--suspect-after`, `--dead-after`). Injection is drawn by
/// [`crate::model::disturbance::FaultInjector`] on its own RNG stream
/// and executed by [`crate::sim::SimTransport`]; with every knob at
/// its default the injector is never constructed and runs are
/// bit-identical to the pre-fault code.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// Per-learner, per-iteration crash probability (0 = never). A
    /// crashed learner swallows its task; its in-flight result is
    /// cancelled.
    pub crash_rate: f64,
    /// Mean downtime of a crash-and-restart (exponential draw). `None`
    /// makes every injected crash permanent.
    pub crash_restart: Option<std::time::Duration>,
    /// Per-result omission probability: the learner computes, sends,
    /// and the result is lost in flight (charged as waste + network
    /// traffic, never delivered).
    pub omission_rate: f64,
    /// Consecutive transport-corroborated missed iterations before a
    /// learner is marked **suspect** (LearnerSuspected event).
    pub suspect_after: u32,
    /// Consecutive misses before a suspect is **declared dead** and
    /// the membership remaps to survivors. Must be ≥ `suspect_after`.
    pub dead_after: u32,
    /// Behavior when survivors cannot reach rank M.
    pub degraded: DegradedMode,
}

impl FaultConfig {
    /// No injection, default detection policy — bit-identical runs.
    pub fn none() -> FaultConfig {
        FaultConfig {
            crash_rate: 0.0,
            crash_restart: None,
            omission_rate: 0.0,
            suspect_after: 2,
            dead_after: 3,
            degraded: DegradedMode::Error,
        }
    }

    /// Whether any fault *injection* is configured (detection and the
    /// degraded path key off transport evidence, not this).
    pub fn injects(&self) -> bool {
        self.crash_rate > 0.0 || self.omission_rate > 0.0
    }

    /// Short human label for run summaries.
    pub fn label(&self) -> String {
        let restart = match self.crash_restart {
            Some(d) => format!(", restart≈{d:?}"),
            None => String::new(),
        };
        format!(
            "crash={}{restart}, omit={}, degraded={}",
            self.crash_rate,
            self.omission_rate,
            self.degraded.name()
        )
    }
}

/// How an injected corruption perturbs a learner's result vector
/// (`--corrupt-mode`). All three modes produce perturbations far above
/// the residual-check tolerance, so a detection miss is a verifier
/// bug, not a marginal-signal artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CorruptMode {
    /// Flip a high (sign/exponent) bit of one element — classic memory
    /// / wire bit-rot that survives a parseable frame.
    Bitflip,
    /// Multiply the whole vector by a large constant — a mis-scaled
    /// gradient (wrong learning rate, fp overflow fallout).
    Scale,
    /// Overwrite the vector with large adversarial values — a
    /// Byzantine learner actively poisoning the aggregate.
    Adversarial,
}

impl CorruptMode {
    pub fn name(&self) -> &'static str {
        match self {
            CorruptMode::Bitflip => "bitflip",
            CorruptMode::Scale => "scale",
            CorruptMode::Adversarial => "adversarial",
        }
    }

    pub fn parse(s: &str) -> Option<CorruptMode> {
        match s {
            "bitflip" => Some(CorruptMode::Bitflip),
            "scale" => Some(CorruptMode::Scale),
            "adversarial" => Some(CorruptMode::Adversarial),
            _ => None,
        }
    }
}

/// Byzantine corruption-injection knobs (`--corrupt-rate`,
/// `--corrupt-mode`). Corruption is drawn by
/// [`crate::model::disturbance::CorruptionInjector`] on its own RNG
/// stream and executed by [`crate::sim::SimTransport`] on the result
/// vector *after* compute — the frame still parses, the length is
/// right, only the payload lies. With the rate at zero the injector is
/// never constructed and runs are bit-identical to the pre-corruption
/// code.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CorruptConfig {
    /// Per-learner, per-iteration corruption probability (0 = never).
    pub rate: f64,
    /// How a drawn corruption perturbs the result vector.
    pub mode: CorruptMode,
}

impl CorruptConfig {
    /// No corruption — bit-identical runs. The mode default (bitflip)
    /// is inert while the rate is zero, so `--corrupt-mode` alone is a
    /// neutral knob (the CI inert-twin relies on this).
    pub fn none() -> CorruptConfig {
        CorruptConfig { rate: 0.0, mode: CorruptMode::Bitflip }
    }

    /// Whether corruption injection is configured.
    pub fn injects(&self) -> bool {
        self.rate > 0.0
    }

    /// Short human label for run summaries.
    pub fn label(&self) -> String {
        format!("rate={}, mode={}", self.rate, self.mode.name())
    }
}

/// Full specification of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Preset name in artifacts/manifest.json (defines env, M, dims).
    pub preset: String,
    /// Number of learners N (paper: 15).
    pub n_learners: usize,
    pub scheme: Scheme,
    pub decode: DecodeMethod,
    /// `p_m` for the random sparse code (paper: 0.8).
    pub p_m: f64,
    pub straggler: StragglerConfig,
    /// Replay measured per-learner latency traces instead of the
    /// synthetic injector (`--trace`; JSONL or CSV, see
    /// [`crate::model::trace`]). Mutually exclusive with the injector
    /// knobs — the single validation point is [`TrainConfig::validate`].
    pub trace: Option<std::path::PathBuf>,
    /// Modeled network link for virtual-time runs (`--bandwidth`,
    /// `--net-jitter-us`); free by default.
    pub net: NetConfig,
    /// Physical fleet layout for the per-link incast model
    /// (`--topology flat|racks:<r>x<w>`); flat (single shared link,
    /// no queueing) by default — bit-identical to the PR 5 model.
    pub topology: Topology,
    /// Rack uplink bandwidth in MB/s for racked topologies
    /// (`--uplink-mbps`; 0 = infinite). Result returns serialize over
    /// their rack's uplink before hitting the controller ingress.
    pub uplink_mbps: f64,
    /// Controller iterations deep the broadcast pipeline runs
    /// (`--pipeline-depth`, 1 or 2). Depth 2 credits the controller
    /// prelude (rollout + sample + encode) against the previous
    /// iteration's collect window; depth 1 is the serial loop. Trained
    /// parameters are bitwise identical at either depth.
    pub pipeline_depth: usize,
    /// Modeled controller prelude cost per non-warmup iteration
    /// (`--ctrl-compute-us`); zero (free, the historical behavior) by
    /// default. This is what pipelining can hide.
    pub ctrl_compute: std::time::Duration,
    /// Worker threads for the per-agent decode apply
    /// (`--decode-threads`; agents are independent columns of
    /// Θ = W·Y, so the split is bit-identical by construction).
    /// 0 = serial.
    pub decode_threads: usize,
    /// Fault injection + failure-handling policy (`--crash-rate`,
    /// `--crash-restart-s`, `--omission-rate`, `--degraded-mode`,
    /// `--suspect-after`, `--dead-after`); no injection by default.
    pub fault: FaultConfig,
    /// Byzantine corruption injection (`--corrupt-rate`,
    /// `--corrupt-mode`); no corruption by default.
    pub corrupt: CorruptConfig,
    /// Verified decode (`--verify-decode`): when arrivals exceed rank
    /// M, spend the surplus rows on a residual parity check and — on a
    /// failed check — an error-locating re-decode that identifies and
    /// excludes the corrupted row (see coding::decoder). Off by
    /// default; on a clean run the verified path is bit-identical to
    /// the unverified one.
    pub verify_decode: bool,
    /// How virtual compute time is modeled (`--compute-model`).
    pub compute_model: ComputeModelCfg,
    /// Training iterations (paper Alg. 1 outer loop).
    pub iterations: usize,
    /// Episodes executed per iteration (Alg. 1 line 3).
    pub episodes_per_iter: usize,
    /// Max steps per episode (Alg. 1 line 4).
    pub episode_len: usize,
    /// Replay buffer capacity.
    pub buffer_capacity: usize,
    /// Iterations of pure exploration before learner updates start
    /// (fills the replay buffer).
    pub warmup_iters: usize,
    /// Exploration noise σ at iteration 0 (Gaussian on actions).
    pub noise_sigma: f64,
    /// Iterations over which σ decays to 10% of its start value.
    pub noise_decay_iters: usize,
    pub backend: Backend,
    /// Mock backend only: synthetic per-agent-update compute time. In
    /// `TimeMode::Virtual` this is the *modeled* virtual cost per
    /// update.
    pub mock_compute: std::time::Duration,
    pub transport: Transport,
    /// Real wall-clock execution or virtual-time simulation.
    pub time_mode: TimeMode,
    /// Worker threads for sharded sweep execution
    /// ([`crate::sim::sweep::run_sweep`]): independent (scheme, k)
    /// cells run concurrently in virtual time. 0 = one per available
    /// core. Real-time sweeps ignore it and run serially (wall-clock
    /// cells must not contend for cores).
    pub sweep_threads: usize,
    pub seed: u64,
    /// Write per-iteration CSV under this directory (None = don't).
    pub out_dir: Option<std::path::PathBuf>,
    /// Save agent parameters to `<out_dir>/checkpoint.bin` every this
    /// many iterations (0 = never). Requires `out_dir`.
    pub checkpoint_every: usize,
    /// Resume initial parameters from this checkpoint file.
    pub resume: Option<std::path::PathBuf>,
    /// Live coding-plan adaptation: the obs-fed selector measures
    /// straggler statistics and installs a new epoch-versioned
    /// [`crate::coding::CodingPlan`] at runtime when another scheme's
    /// expected iteration time is clearly lower (extension beyond the
    /// paper; see coordinator::adaptive).
    pub adaptive: bool,
    /// Score the schemes only every this-many observations past warmup
    /// (`--adapt-every`, default 1 = every iteration).
    pub adapt_every: usize,
    /// Observations before the selector recommends anything
    /// (`--adapt-min-obs`, default 5).
    pub adapt_min_obs: usize,
    /// Relative improvement a challenger needs over the incumbent
    /// (`--adapt-hysteresis`, default 0.1 = 10%).
    pub adapt_hysteresis: f64,
    /// Give up on an iteration when no decodable subset arrives within
    /// this window — covers crashed learners / dead workers. In a
    /// healthy run all N results arrive and rank(C) = M guarantees
    /// decodability.
    pub collect_timeout: std::time::Duration,
    /// Print per-iteration progress lines.
    pub verbose: bool,
    /// Write a Chrome trace-event file of the run here (`--trace-out`;
    /// one lane per learner, Perfetto-loadable) plus a JSONL event log
    /// next to it. `None` (the default) keeps event tracing fully off —
    /// the run is bit-identical to a build without the obs layer.
    /// Distinct from `trace`, which *replays* measured delays.
    pub trace_out: Option<std::path::PathBuf>,
}

impl TrainConfig {
    /// Defaults mirroring the paper's setup (§V-C): N = 15 learners,
    /// p_m = 0.8, 50 iterations.
    pub fn new(preset: impl Into<String>) -> TrainConfig {
        TrainConfig {
            preset: preset.into(),
            n_learners: 15,
            scheme: Scheme::Mds,
            decode: DecodeMethod::Auto,
            p_m: 0.8,
            straggler: StragglerConfig::none(),
            trace: None,
            net: NetConfig::free(),
            topology: Topology::Flat,
            uplink_mbps: 0.0,
            pipeline_depth: 1,
            ctrl_compute: std::time::Duration::ZERO,
            decode_threads: 0,
            fault: FaultConfig::none(),
            corrupt: CorruptConfig::none(),
            verify_decode: false,
            compute_model: ComputeModelCfg::Fixed,
            iterations: 50,
            episodes_per_iter: 2,
            episode_len: 25,
            buffer_capacity: 100_000,
            warmup_iters: 2,
            noise_sigma: 0.3,
            noise_decay_iters: 200,
            backend: Backend::Pjrt,
            mock_compute: std::time::Duration::from_millis(2),
            transport: Transport::Local,
            time_mode: TimeMode::Real,
            sweep_threads: 0,
            seed: 0,
            out_dir: None,
            checkpoint_every: 0,
            resume: None,
            adaptive: false,
            adapt_every: 1,
            adapt_min_obs: 5,
            adapt_hysteresis: 0.1,
            collect_timeout: std::time::Duration::from_secs(120),
            verbose: false,
            trace_out: None,
        }
    }

    /// Parse the shared CLI surface. Unknown flags error; every flag is
    /// optional except `--preset`.
    pub fn from_args(args: &Args) -> Result<TrainConfig> {
        let mut cfg = TrainConfig::new(args.required("preset")?);
        if let Some(v) = args.opt("learners") {
            cfg.n_learners = v.parse()?;
        }
        if let Some(v) = args.opt("scheme") {
            cfg.scheme = Scheme::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown scheme '{v}' (want one of: uncoded, replication, mds, random_sparse, ldpc)"))?;
        }
        if let Some(v) = args.opt("decode") {
            cfg.decode = DecodeMethod::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown decode method '{v}'"))?;
        }
        if let Some(v) = args.opt("p-m") {
            cfg.p_m = v.parse()?;
            if !(0.0..=1.0).contains(&cfg.p_m) {
                bail!("--p-m must be in [0, 1]");
            }
        }
        if let Some(v) = args.opt("stragglers") {
            cfg.straggler.k = v.parse()?;
        }
        if let Some(v) = args.opt("straggler-delay-ms") {
            cfg.straggler.delay = std::time::Duration::from_millis(v.parse()?);
        }
        if args.flag("straggler-exponential") {
            cfg.straggler.dist = DelayDist::Exponential;
        }
        // Shape knobs are read unconditionally so `args.finish()` never
        // flags them as unknown when `--delay-dist` is absent.
        let delay_alpha = args.get_or("delay-alpha", DelayDist::DEFAULT_ALPHA)?;
        let delay_sigma = args.get_or("delay-sigma", DelayDist::DEFAULT_SIGMA)?;
        if let Some(v) = args.opt("delay-dist") {
            cfg.straggler.dist = DelayDist::parse(v, delay_alpha, delay_sigma)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown delay distribution '{v}' \
                         (fixed|exponential|pareto|lognormal)"
                    )
                })?;
        }
        cfg.apply_model_args(args)?;
        if let Some(v) = args.opt("iterations") {
            cfg.iterations = v.parse()?;
        }
        if let Some(v) = args.opt("episodes") {
            cfg.episodes_per_iter = v.parse()?;
        }
        if let Some(v) = args.opt("episode-len") {
            cfg.episode_len = v.parse()?;
        }
        if let Some(v) = args.opt("buffer") {
            cfg.buffer_capacity = v.parse()?;
        }
        if let Some(v) = args.opt("warmup") {
            cfg.warmup_iters = v.parse()?;
        }
        if let Some(v) = args.opt("noise") {
            cfg.noise_sigma = v.parse()?;
        }
        if let Some(v) = args.opt("noise-decay") {
            cfg.noise_decay_iters = v.parse()?;
        }
        if let Some(v) = args.opt("backend") {
            cfg.backend = Backend::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown backend '{v}' (pjrt|mock)"))?;
        }
        if let Some(v) = args.opt("mock-compute-us") {
            cfg.mock_compute = std::time::Duration::from_micros(v.parse()?);
        }
        if let Some(v) = args.opt("transport") {
            cfg.transport = Transport::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown transport '{v}' (local|tcp)"))?;
        }
        if let Some(v) = args.opt("time-mode") {
            cfg.time_mode = TimeMode::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown time mode '{v}' (real|virtual)"))?;
        }
        if let Some(v) = args.opt("sweep-threads") {
            cfg.sweep_threads = v.parse()?;
        }
        if let Some(v) = args.opt("seed") {
            cfg.seed = v.parse()?;
        }
        if let Some(v) = args.opt("out-dir") {
            cfg.out_dir = Some(v.into());
        }
        if let Some(v) = args.opt("checkpoint-every") {
            cfg.checkpoint_every = v.parse()?;
        }
        if let Some(v) = args.opt("resume") {
            cfg.resume = Some(v.into());
        }
        if let Some(v) = args.opt("collect-timeout-ms") {
            cfg.collect_timeout = std::time::Duration::from_millis(v.parse()?);
        }
        if let Some(v) = args.opt("trace-out") {
            cfg.trace_out = Some(v.into());
        }
        if args.flag("verbose") {
            cfg.verbose = true;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse the system-model flag surface (`--trace`, `--bandwidth`,
    /// `--net-jitter-us`, `--compute-model`) plus the fault knobs
    /// (`--crash-rate`, `--crash-restart-s`, `--omission-rate`,
    /// `--degraded-mode`, `--suspect-after`, `--dead-after`) and the
    /// adaptive-plan knobs (`--adaptive`, `--adapt-every`,
    /// `--adapt-min-obs`, `--adapt-hysteresis`) — shared by
    /// [`TrainConfig::from_args`] and the sweep subcommands, which
    /// build their base config through `sweep_base` instead.
    pub fn apply_model_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.opt("trace") {
            self.trace = Some(v.into());
        }
        if let Some(v) = args.opt("bandwidth") {
            self.net.bandwidth_mbps = v.parse()?;
        }
        if let Some(v) = args.opt("net-jitter-us") {
            self.net.jitter = std::time::Duration::from_micros(v.parse()?);
        }
        if let Some(v) = args.opt("topology") {
            self.topology = Topology::parse(v).ok_or_else(|| {
                anyhow::anyhow!("unknown topology '{v}' (flat|racks:<r>x<w>)")
            })?;
        }
        if let Some(v) = args.opt("uplink-mbps") {
            self.uplink_mbps = v.parse()?;
        }
        if let Some(v) = args.opt("pipeline-depth") {
            self.pipeline_depth = v.parse()?;
        }
        if let Some(v) = args.opt("ctrl-compute-us") {
            self.ctrl_compute = std::time::Duration::from_micros(v.parse()?);
        }
        if let Some(v) = args.opt("decode-threads") {
            self.decode_threads = v.parse()?;
        }
        if let Some(v) = args.opt("compute-model") {
            self.compute_model = ComputeModelCfg::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown compute model '{v}' (fixed|calibrated)"))?;
        }
        if let Some(v) = args.opt("crash-rate") {
            self.fault.crash_rate = v.parse()?;
        }
        if let Some(v) = args.opt("crash-restart-s") {
            let secs: f64 = v.parse()?;
            if !secs.is_finite() || secs <= 0.0 {
                bail!("--crash-restart-s must be a finite mean downtime > 0 s, got {v}");
            }
            self.fault.crash_restart = Some(std::time::Duration::from_secs_f64(secs));
        }
        if let Some(v) = args.opt("omission-rate") {
            self.fault.omission_rate = v.parse()?;
        }
        if let Some(v) = args.opt("degraded-mode") {
            self.fault.degraded = DegradedMode::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown degraded mode '{v}' (error|uncoded)"))?;
        }
        if let Some(v) = args.opt("suspect-after") {
            self.fault.suspect_after = v.parse()?;
        }
        if let Some(v) = args.opt("dead-after") {
            self.fault.dead_after = v.parse()?;
        }
        if let Some(v) = args.opt("corrupt-rate") {
            self.corrupt.rate = v.parse()?;
        }
        if let Some(v) = args.opt("corrupt-mode") {
            self.corrupt.mode = CorruptMode::parse(v).ok_or_else(|| {
                anyhow::anyhow!("unknown corrupt mode '{v}' (bitflip|scale|adversarial)")
            })?;
        }
        if args.flag("verify-decode") {
            self.verify_decode = true;
        }
        if args.flag("adaptive") {
            self.adaptive = true;
        }
        if let Some(v) = args.opt("adapt-every") {
            self.adapt_every = v.parse()?;
        }
        if let Some(v) = args.opt("adapt-min-obs") {
            self.adapt_min_obs = v.parse()?;
        }
        if let Some(v) = args.opt("adapt-hysteresis") {
            self.adapt_hysteresis = v.parse()?;
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_learners == 0 {
            bail!("need at least one learner");
        }
        if self.straggler.k > self.n_learners {
            bail!(
                "stragglers k={} exceeds learner count N={}",
                self.straggler.k, self.n_learners
            );
        }
        if self.iterations == 0 {
            bail!("iterations must be > 0");
        }
        if self.episode_len == 0 || self.episodes_per_iter == 0 {
            bail!("episode schedule must be > 0");
        }
        if self.checkpoint_every > 0 && self.out_dir.is_none() {
            bail!("--checkpoint-every requires --out-dir");
        }
        if self.collect_timeout.is_zero() {
            bail!("collect timeout must be > 0");
        }
        match self.straggler.dist {
            DelayDist::Pareto { alpha } if alpha <= 1.0 => {
                bail!("pareto delay shape must satisfy alpha > 1 (finite mean), got {alpha}");
            }
            DelayDist::LogNormal { sigma } if sigma <= 0.0 => {
                bail!("lognormal delay shape must satisfy sigma > 0, got {sigma}");
            }
            _ => {}
        }
        if !self.net.bandwidth_mbps.is_finite() || self.net.bandwidth_mbps < 0.0 {
            bail!(
                "--bandwidth must be a finite MB/s value ≥ 0 (0 = infinite), got {}",
                self.net.bandwidth_mbps
            );
        }
        if !(1..=2).contains(&self.pipeline_depth) {
            bail!("--pipeline-depth must be 1 or 2, got {}", self.pipeline_depth);
        }
        if !self.uplink_mbps.is_finite() || self.uplink_mbps < 0.0 {
            bail!(
                "--uplink-mbps must be a finite MB/s value ≥ 0 (0 = infinite), got {}",
                self.uplink_mbps
            );
        }
        if let Topology::Racks { racks, width } = self.topology {
            if racks == 0 || width == 0 {
                bail!("--topology racks:<r>x<w> needs r ≥ 1 and w ≥ 1, got racks:{racks}x{width}");
            }
            if racks * width < self.n_learners {
                bail!(
                    "--topology racks:{racks}x{width} has {} slots but N={} learners",
                    racks * width,
                    self.n_learners
                );
            }
        }
        if self.uplink_mbps > 0.0 && self.topology == Topology::Flat {
            bail!("--uplink-mbps models rack uplinks; pass --topology racks:<r>x<w>");
        }
        if self.time_mode != TimeMode::Virtual
            && (self.pipeline_depth > 1
                || !self.ctrl_compute.is_zero()
                || self.topology != Topology::Flat
                || self.uplink_mbps > 0.0)
        {
            bail!(
                "--pipeline-depth 2/--ctrl-compute-us/--topology/--uplink-mbps are \
                 virtual-time models; pass --time-mode virtual"
            );
        }
        if self.trace.is_some()
            && (self.straggler.k > 0
                || !self.straggler.delay.is_zero()
                || self.straggler.dist != DelayDist::Fixed)
        {
            bail!(
                "--trace replays measured per-learner delays and cannot be combined with \
                 the synthetic injector flags (--stragglers / --straggler-delay-ms / \
                 --delay-dist / --straggler-exponential)"
            );
        }
        for (name, rate) in [
            ("--crash-rate", self.fault.crash_rate),
            ("--omission-rate", self.fault.omission_rate),
            ("--corrupt-rate", self.corrupt.rate),
        ] {
            if !rate.is_finite() || !(0.0..=1.0).contains(&rate) {
                bail!("{name} must be a probability in [0, 1], got {rate}");
            }
        }
        if self.fault.crash_restart.is_some() && self.fault.crash_rate == 0.0 {
            bail!("--crash-restart-s only makes sense with --crash-rate > 0");
        }
        if self.fault.suspect_after == 0 || self.fault.dead_after < self.fault.suspect_after {
            bail!(
                "failure-detection policy needs 1 <= --suspect-after <= --dead-after, \
                 got suspect_after={} dead_after={}",
                self.fault.suspect_after,
                self.fault.dead_after
            );
        }
        if self.adapt_every == 0 {
            bail!("--adapt-every must be >= 1");
        }
        if !self.adapt_hysteresis.is_finite() || self.adapt_hysteresis < 0.0 {
            bail!(
                "--adapt-hysteresis must be a finite relative margin >= 0, got {}",
                self.adapt_hysteresis
            );
        }
        if self.fault.injects() && self.time_mode != TimeMode::Virtual {
            bail!(
                "--crash-rate/--omission-rate inject faults in the discrete-event \
                 simulator; pass --time-mode virtual (real transports surface real \
                 connection failures instead)"
            );
        }
        if self.corrupt.injects() && self.time_mode != TimeMode::Virtual {
            bail!(
                "--corrupt-rate injects result corruption in the discrete-event \
                 simulator; pass --time-mode virtual (real transports surface real \
                 corruption through the wire-level CRC instead)"
            );
        }
        if self.time_mode == TimeMode::Virtual && self.transport != Transport::Local {
            bail!(
                "--time-mode virtual requires --transport local \
                 (simulated learners live in the controller process)"
            );
        }
        if self.time_mode == TimeMode::Real
            && (!self.net.is_free() || self.compute_model != ComputeModelCfg::Fixed)
        {
            // These models exist only in the discrete-event transport;
            // silently ignoring them in real time would let a user
            // believe a modeled link/compute distribution was applied.
            bail!(
                "--bandwidth/--net-jitter-us/--compute-model are virtual-time models; \
                 pass --time-mode virtual (real transports measure real transfer and \
                 compute). --trace works in both modes."
            );
        }
        // Note: `TimeMode::Virtual` no longer requires `Backend::Mock`
        // — the sim runs any backend's numerics and charges time via
        // the compute model (`--compute-model calibrated` measures the
        // real backend at pool startup).
        Ok(())
    }

    /// One-line human summary for run headers.
    pub fn summary(&self) -> String {
        let disturbance = match &self.trace {
            Some(path) => format!("trace={}", path.display()),
            None => format!(
                "stragglers(k={}, t_s={:?}{})",
                self.straggler.k,
                self.straggler.delay,
                match self.straggler.dist {
                    DelayDist::Fixed => String::new(),
                    d => format!(", {}", d.label()),
                },
            ),
        };
        let mut model = String::new();
        if !self.net.is_free() {
            model.push_str(&format!(" net={}", self.net.label()));
        }
        if self.topology != Topology::Flat {
            model.push_str(&format!(" topo={}", self.topology.label()));
            if self.uplink_mbps > 0.0 {
                model.push_str(&format!(" uplink={}MB/s", self.uplink_mbps));
            }
        }
        if self.pipeline_depth > 1 {
            model.push_str(&format!(" pipeline=depth{}", self.pipeline_depth));
        }
        if !self.ctrl_compute.is_zero() {
            model.push_str(&format!(" ctrl-compute={:?}", self.ctrl_compute));
        }
        if self.decode_threads > 1 {
            model.push_str(&format!(" decode-threads={}", self.decode_threads));
        }
        if self.compute_model != ComputeModelCfg::Fixed {
            model.push_str(&format!(" compute={}", self.compute_model.name()));
        }
        if self.fault.injects() {
            model.push_str(&format!(" faults({})", self.fault.label()));
        }
        if self.corrupt.injects() {
            model.push_str(&format!(" corrupt({})", self.corrupt.label()));
        }
        if self.verify_decode {
            model.push_str(" verify-decode");
        }
        format!(
            "preset={} N={} scheme={} decode={} {disturbance} iters={} backend={} transport={} time={}{model} seed={}",
            self.preset,
            self.n_learners,
            self.scheme,
            self.decode.name(),
            self.iterations,
            self.backend.name(),
            self.transport.name(),
            self.time_mode.name(),
            self.seed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Result<TrainConfig> {
        let args = Args::parse(argv.iter().map(|s| s.to_string()))?;
        TrainConfig::from_args(&args)
    }

    #[test]
    fn defaults_match_paper() {
        let cfg = parse(&["--preset", "coop_nav_m8"]).unwrap();
        assert_eq!(cfg.n_learners, 15);
        assert_eq!(cfg.p_m, 0.8);
        assert_eq!(cfg.scheme, Scheme::Mds);
        assert_eq!(cfg.straggler.k, 0);
    }

    #[test]
    fn full_flag_surface() {
        let cfg = parse(&[
            "--preset", "keep_away_m10",
            "--learners", "15",
            "--scheme", "ldpc",
            "--decode", "peeling",
            "--stragglers", "5",
            "--straggler-delay-ms", "150",
            "--straggler-exponential",
            "--iterations", "10",
            "--episodes", "3",
            "--episode-len", "30",
            "--backend", "mock",
            "--mock-compute-us", "500",
            "--transport", "tcp",
            "--seed", "9",
            "--verbose",
        ])
        .unwrap();
        assert_eq!(cfg.scheme, Scheme::Ldpc);
        assert_eq!(cfg.decode, DecodeMethod::Peeling);
        assert_eq!(cfg.straggler.k, 5);
        assert_eq!(cfg.straggler.delay, std::time::Duration::from_millis(150));
        assert_eq!(cfg.straggler.dist, DelayDist::Exponential);
        assert_eq!(cfg.backend, Backend::Mock);
        assert_eq!(cfg.mock_compute, std::time::Duration::from_micros(500));
        assert_eq!(cfg.transport, Transport::Tcp);
        assert!(cfg.verbose);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(parse(&[]).is_err(), "preset is required");
        assert!(parse(&["--preset", "x", "--scheme", "nope"]).is_err());
        assert!(parse(&["--preset", "x", "--learners", "0"]).is_err());
        assert!(parse(&["--preset", "x", "--stragglers", "99"]).is_err());
        assert!(parse(&["--preset", "x", "--p-m", "1.5"]).is_err());
        assert!(parse(&["--preset", "x", "--iterations", "0"]).is_err());
    }

    #[test]
    fn delay_dist_parses_with_shape_knobs() {
        let cfg = parse(&["--preset", "x"]).unwrap();
        assert_eq!(cfg.straggler.dist, DelayDist::Fixed);
        let cfg = parse(&["--preset", "x", "--delay-dist", "pareto"]).unwrap();
        assert_eq!(cfg.straggler.dist, DelayDist::Pareto { alpha: 1.5 });
        let cfg =
            parse(&["--preset", "x", "--delay-dist", "pareto", "--delay-alpha", "2.5"]).unwrap();
        assert_eq!(cfg.straggler.dist, DelayDist::Pareto { alpha: 2.5 });
        let cfg =
            parse(&["--preset", "x", "--delay-dist", "lognormal", "--delay-sigma", "0.5"]).unwrap();
        assert_eq!(cfg.straggler.dist, DelayDist::LogNormal { sigma: 0.5 });
        let cfg = parse(&["--preset", "x", "--delay-dist", "exp"]).unwrap();
        assert_eq!(cfg.straggler.dist, DelayDist::Exponential);
        // shape validation: infinite-mean pareto and degenerate lognormal
        assert!(parse(&["--preset", "x", "--delay-dist", "pareto", "--delay-alpha", "1.0"])
            .is_err());
        assert!(parse(&["--preset", "x", "--delay-dist", "lognormal", "--delay-sigma", "0"])
            .is_err());
        assert!(parse(&["--preset", "x", "--delay-dist", "weibull"]).is_err());
        // legacy switch stays an alias
        let cfg = parse(&["--preset", "x", "--straggler-exponential"]).unwrap();
        assert_eq!(cfg.straggler.dist, DelayDist::Exponential);
        // summary names the tail
        let mut c = TrainConfig::new("x");
        c.straggler.dist = DelayDist::Pareto { alpha: 1.5 };
        assert!(c.summary().contains("pareto"));
    }

    #[test]
    fn sweep_threads_parses_with_auto_default() {
        let cfg = parse(&["--preset", "x"]).unwrap();
        assert_eq!(cfg.sweep_threads, 0, "default is auto (one per core)");
        let cfg = parse(&["--preset", "x", "--sweep-threads", "6"]).unwrap();
        assert_eq!(cfg.sweep_threads, 6);
        assert!(parse(&["--preset", "x", "--sweep-threads", "lots"]).is_err());
    }

    #[test]
    fn adaptive_flags_parse_and_are_validated() {
        let cfg = parse(&["--preset", "x"]).unwrap();
        assert!(!cfg.adaptive, "adaptive plan switching is opt-in");
        assert_eq!(cfg.adapt_every, 1);
        assert_eq!(cfg.adapt_min_obs, 5);
        assert_eq!(cfg.adapt_hysteresis, 0.1);
        let cfg = parse(&[
            "--preset", "x", "--adaptive",
            "--adapt-every", "2",
            "--adapt-min-obs", "3",
            "--adapt-hysteresis", "0.2",
        ])
        .unwrap();
        assert!(cfg.adaptive);
        assert_eq!(cfg.adapt_every, 2);
        assert_eq!(cfg.adapt_min_obs, 3);
        assert_eq!(cfg.adapt_hysteresis, 0.2);
        assert!(parse(&["--preset", "x", "--adapt-every", "0"]).is_err());
        assert!(parse(&["--preset", "x", "--adapt-hysteresis", "-0.1"]).is_err());
        assert!(parse(&["--preset", "x", "--adapt-hysteresis", "inf"]).is_err());
    }

    #[test]
    fn time_mode_parses_and_is_validated() {
        let cfg = parse(&["--preset", "x", "--time-mode", "virtual", "--backend", "mock"]).unwrap();
        assert_eq!(cfg.time_mode, TimeMode::Virtual);
        let cfg = parse(&["--preset", "x"]).unwrap();
        assert_eq!(cfg.time_mode, TimeMode::Real);
        // PJRT + virtual is now allowed: the compute model charges the
        // time, the backend only supplies the numerics (ISSUE 5 lifts
        // the old mock-only restriction).
        assert!(parse(&["--preset", "x", "--time-mode", "virtual"]).is_ok());
        // TCP stays rejected: simulated learners are in-process.
        assert!(parse(&[
            "--preset", "x", "--time-mode", "virtual", "--backend", "mock", "--transport", "tcp",
        ])
        .is_err());
        assert!(parse(&["--preset", "x", "--time-mode", "warp"]).is_err());
        assert_eq!(TimeMode::parse("real"), Some(TimeMode::Real));
        assert_eq!(TimeMode::parse("virtual"), Some(TimeMode::Virtual));
        assert_eq!(TimeMode::parse(""), None);
    }

    #[test]
    fn summary_mentions_time_mode() {
        let mut cfg = TrainConfig::new("x");
        cfg.backend = Backend::Mock;
        cfg.time_mode = TimeMode::Virtual;
        assert!(cfg.summary().contains("time=virtual"));
    }

    #[test]
    fn backend_transport_parse() {
        assert_eq!(Backend::parse("pjrt"), Some(Backend::Pjrt));
        assert_eq!(Backend::parse("mock"), Some(Backend::Mock));
        assert_eq!(Backend::parse(""), None);
        assert_eq!(Transport::parse("local"), Some(Transport::Local));
        assert_eq!(Transport::parse("tcp"), Some(Transport::Tcp));
        assert_eq!(Transport::parse("x"), None);
    }

    #[test]
    fn model_flags_parse_with_neutral_defaults() {
        let cfg = parse(&["--preset", "x"]).unwrap();
        assert_eq!(cfg.net, NetConfig::free());
        assert!(cfg.net.is_free());
        assert_eq!(cfg.compute_model, ComputeModelCfg::Fixed);
        assert!(cfg.trace.is_none());

        let cfg = parse(&[
            "--preset", "x",
            "--time-mode", "virtual",
            "--bandwidth", "125",
            "--net-jitter-us", "200",
            "--compute-model", "calibrated",
        ])
        .unwrap();
        assert_eq!(cfg.net.bandwidth_mbps, 125.0);
        assert_eq!(cfg.net.jitter, std::time::Duration::from_micros(200));
        assert_eq!(cfg.compute_model, ComputeModelCfg::Calibrated);
        assert!(cfg.summary().contains("net=125MB/s"), "{}", cfg.summary());
        assert!(cfg.summary().contains("compute=calibrated"), "{}", cfg.summary());

        // trace works in BOTH time modes (real learners sleep the
        // recorded delay; the sim charges it on the event clock)
        let cfg = parse(&["--preset", "x", "--trace", "traces/ec2.jsonl"]).unwrap();
        assert_eq!(cfg.trace.as_deref(), Some(std::path::Path::new("traces/ec2.jsonl")));
        assert!(cfg.summary().contains("trace=traces/ec2.jsonl"), "{}", cfg.summary());

        // ...but the network/compute models are virtual-only: silently
        // modeling nothing in real time would mislead the user
        assert!(parse(&["--preset", "x", "--bandwidth", "25"]).is_err());
        assert!(parse(&["--preset", "x", "--net-jitter-us", "200"]).is_err());
        assert!(parse(&["--preset", "x", "--compute-model", "calibrated"]).is_err());

        // validation: bandwidth must be finite and non-negative
        let virt = |bw: &str| {
            parse(&["--preset", "x", "--time-mode", "virtual", "--bandwidth", bw])
        };
        assert!(virt("-1").is_err());
        assert!(virt("inf").is_err());
        assert!(virt("NaN").is_err());
        assert!(virt("125").is_ok());
        assert!(parse(&["--preset", "x", "--compute-model", "psychic"]).is_err());
    }

    #[test]
    fn trace_conflicts_with_the_synthetic_injector() {
        let both = |extra: &[&str]| {
            let mut argv = vec!["--preset", "x", "--trace", "t.jsonl"];
            argv.extend_from_slice(extra);
            parse(&argv)
        };
        assert!(both(&[]).is_ok(), "trace alone is fine");
        assert!(both(&["--stragglers", "2"]).is_err());
        assert!(both(&["--straggler-delay-ms", "100"]).is_err());
        assert!(both(&["--delay-dist", "pareto"]).is_err());
        assert!(both(&["--straggler-exponential"]).is_err());
    }

    #[test]
    fn net_config_labels() {
        assert_eq!(NetConfig::free().label(), "free");
        let n = NetConfig { bandwidth_mbps: 125.0, jitter: std::time::Duration::ZERO };
        assert_eq!(n.label(), "125MB/s");
        let n = NetConfig { bandwidth_mbps: 0.0, jitter: std::time::Duration::from_micros(50) };
        assert!(n.label().starts_with("inf+j"), "{}", n.label());
        assert!(!n.is_free(), "pure jitter still charges time");
    }

    #[test]
    fn trace_out_parses_and_defaults_off() {
        let cfg = parse(&["--preset", "x"]).unwrap();
        assert!(cfg.trace_out.is_none(), "tracing must be off by default");
        let cfg = parse(&["--preset", "x", "--trace-out", "run.trace.json"]).unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some(std::path::Path::new("run.trace.json")));
        // orthogonal to --trace (input replay): both may be set
        let cfg = parse(&[
            "--preset", "x", "--trace", "t.jsonl", "--trace-out", "out.trace.json",
        ])
        .unwrap();
        assert!(cfg.trace.is_some() && cfg.trace_out.is_some());
    }

    #[test]
    fn fault_flags_parse_with_neutral_defaults() {
        let cfg = parse(&["--preset", "x"]).unwrap();
        assert_eq!(cfg.fault, FaultConfig::none());
        assert!(!cfg.fault.injects(), "no injection by default");
        assert!(!cfg.summary().contains("faults("), "{}", cfg.summary());

        let cfg = parse(&[
            "--preset", "x",
            "--time-mode", "virtual",
            "--crash-rate", "0.05",
            "--crash-restart-s", "2.5",
            "--omission-rate", "0.01",
            "--degraded-mode", "uncoded",
            "--suspect-after", "1",
            "--dead-after", "2",
        ])
        .unwrap();
        assert_eq!(cfg.fault.crash_rate, 0.05);
        assert_eq!(cfg.fault.crash_restart, Some(std::time::Duration::from_secs_f64(2.5)));
        assert_eq!(cfg.fault.omission_rate, 0.01);
        assert_eq!(cfg.fault.degraded, DegradedMode::Uncoded);
        assert_eq!((cfg.fault.suspect_after, cfg.fault.dead_after), (1, 2));
        assert!(cfg.fault.injects());
        assert!(cfg.summary().contains("faults("), "{}", cfg.summary());
        assert!(cfg.summary().contains("degraded=uncoded"), "{}", cfg.summary());
    }

    #[test]
    fn fault_flags_are_validated() {
        let virt = |extra: &[&str]| {
            let mut argv = vec!["--preset", "x", "--time-mode", "virtual"];
            argv.extend_from_slice(extra);
            parse(&argv)
        };
        // rates are probabilities
        assert!(virt(&["--crash-rate", "1.5"]).is_err());
        assert!(virt(&["--crash-rate", "-0.1"]).is_err());
        assert!(virt(&["--omission-rate", "NaN"]).is_err());
        assert!(virt(&["--crash-rate", "1"]).is_ok());
        // restart needs a crash rate and a positive mean
        assert!(virt(&["--crash-restart-s", "2"]).is_err());
        assert!(virt(&["--crash-rate", "0.1", "--crash-restart-s", "0"]).is_err());
        assert!(virt(&["--crash-rate", "0.1", "--crash-restart-s", "2"]).is_ok());
        // detection policy ordering
        assert!(virt(&["--suspect-after", "0"]).is_err());
        assert!(virt(&["--suspect-after", "5", "--dead-after", "2"]).is_err());
        // injection is sim-only
        assert!(parse(&["--preset", "x", "--crash-rate", "0.1"]).is_err());
        assert!(parse(&["--preset", "x", "--omission-rate", "0.1"]).is_err());
        // unknown degraded mode
        assert!(virt(&["--degraded-mode", "panic"]).is_err());
        assert_eq!(DegradedMode::parse("error"), Some(DegradedMode::Error));
        assert_eq!(DegradedMode::parse("uncoded"), Some(DegradedMode::Uncoded));
        assert_eq!(DegradedMode::parse(""), None);
    }

    #[test]
    fn byzantine_flags_parse_with_neutral_defaults() {
        let cfg = parse(&["--preset", "x"]).unwrap();
        assert_eq!(cfg.corrupt, CorruptConfig::none());
        assert!(!cfg.corrupt.injects(), "no corruption by default");
        assert!(!cfg.verify_decode, "verified decode is opt-in");
        assert!(!cfg.summary().contains("corrupt("), "{}", cfg.summary());

        let cfg = parse(&[
            "--preset", "x",
            "--time-mode", "virtual",
            "--corrupt-rate", "0.02",
            "--corrupt-mode", "scale",
            "--verify-decode",
        ])
        .unwrap();
        assert_eq!(cfg.corrupt.rate, 0.02);
        assert_eq!(cfg.corrupt.mode, CorruptMode::Scale);
        assert!(cfg.corrupt.injects());
        assert!(cfg.verify_decode);
        assert!(cfg.summary().contains("corrupt("), "{}", cfg.summary());
        assert!(cfg.summary().contains("verify-decode"), "{}", cfg.summary());

        // Inert knobs must parse without virtual time: a rate of zero
        // plus an explicit mode and --verify-decode is exactly the CI
        // inert-twin invocation, and must be accepted everywhere.
        let cfg = parse(&[
            "--preset", "x", "--corrupt-rate", "0", "--corrupt-mode", "bitflip",
            "--verify-decode",
        ])
        .unwrap();
        assert!(!cfg.corrupt.injects());
        assert!(cfg.verify_decode);
    }

    #[test]
    fn byzantine_flags_are_validated() {
        let virt = |extra: &[&str]| {
            let mut argv = vec!["--preset", "x", "--time-mode", "virtual"];
            argv.extend_from_slice(extra);
            parse(&argv)
        };
        // rate is a probability
        assert!(virt(&["--corrupt-rate", "1.5"]).is_err());
        assert!(virt(&["--corrupt-rate", "-0.1"]).is_err());
        assert!(virt(&["--corrupt-rate", "NaN"]).is_err());
        assert!(virt(&["--corrupt-rate", "1"]).is_ok());
        // injection is sim-only; the neutral knob is not
        assert!(parse(&["--preset", "x", "--corrupt-rate", "0.1"]).is_err());
        assert!(parse(&["--preset", "x", "--corrupt-mode", "scale"]).is_ok());
        assert!(parse(&["--preset", "x", "--verify-decode"]).is_ok());
        // unknown mode
        assert!(virt(&["--corrupt-mode", "gremlins"]).is_err());
        assert_eq!(CorruptMode::parse("bitflip"), Some(CorruptMode::Bitflip));
        assert_eq!(CorruptMode::parse("scale"), Some(CorruptMode::Scale));
        assert_eq!(CorruptMode::parse("adversarial"), Some(CorruptMode::Adversarial));
        assert_eq!(CorruptMode::parse(""), None);
    }

    #[test]
    fn topology_parses_and_maps_racks() {
        assert_eq!(Topology::parse("flat"), Some(Topology::Flat));
        assert_eq!(Topology::parse("racks:4x4"), Some(Topology::Racks { racks: 4, width: 4 }));
        assert_eq!(Topology::parse("racks:4"), None);
        assert_eq!(Topology::parse("racks:x4"), None);
        assert_eq!(Topology::parse("mesh"), None);
        let t = Topology::Racks { racks: 4, width: 4 };
        assert_eq!(t.label(), "racks:4x4");
        assert_eq!(t.rack_of(0), Some(0));
        assert_eq!(t.rack_of(3), Some(0));
        assert_eq!(t.rack_of(4), Some(1));
        assert_eq!(t.rack_of(15), Some(3));
        assert_eq!(t.rack_count(), 4);
        assert_eq!(Topology::Flat.rack_of(7), None);
        assert_eq!(Topology::Flat.rack_count(), 1);
    }

    #[test]
    fn pipeline_flags_parse_with_neutral_defaults() {
        let cfg = parse(&["--preset", "x"]).unwrap();
        assert_eq!(cfg.pipeline_depth, 1);
        assert_eq!(cfg.topology, Topology::Flat);
        assert_eq!(cfg.uplink_mbps, 0.0);
        assert_eq!(cfg.ctrl_compute, std::time::Duration::ZERO);
        assert_eq!(cfg.decode_threads, 0);

        let cfg = parse(&[
            "--preset", "x",
            "--time-mode", "virtual",
            "--pipeline-depth", "2",
            "--ctrl-compute-us", "500",
            "--topology", "racks:4x4",
            "--uplink-mbps", "50",
            "--decode-threads", "4",
        ])
        .unwrap();
        assert_eq!(cfg.pipeline_depth, 2);
        assert_eq!(cfg.ctrl_compute, std::time::Duration::from_micros(500));
        assert_eq!(cfg.topology, Topology::Racks { racks: 4, width: 4 });
        assert_eq!(cfg.uplink_mbps, 50.0);
        assert_eq!(cfg.decode_threads, 4);
        assert!(cfg.summary().contains("pipeline=depth2"), "{}", cfg.summary());
        assert!(cfg.summary().contains("topo=racks:4x4"), "{}", cfg.summary());

        // decode-threads is a pure implementation knob: legal in any
        // time mode (the split is bit-identical by construction).
        assert!(parse(&["--preset", "x", "--decode-threads", "8"]).is_ok());
        // explicit defaults stay legal everywhere — the CI inert twin
        // passes them on a real-time-defaulted command line.
        assert!(parse(&[
            "--preset", "x", "--pipeline-depth", "1", "--topology", "flat",
            "--ctrl-compute-us", "0", "--decode-threads", "0",
        ])
        .is_ok());
    }

    #[test]
    fn pipeline_flags_are_validated() {
        let virt = |extra: &[&str]| {
            let mut argv = vec!["--preset", "x", "--time-mode", "virtual"];
            argv.extend_from_slice(extra);
            parse(&argv)
        };
        // depth is 1 or 2
        assert!(virt(&["--pipeline-depth", "0"]).is_err());
        assert!(virt(&["--pipeline-depth", "3"]).is_err());
        assert!(virt(&["--pipeline-depth", "2"]).is_ok());
        // racks must cover the fleet
        assert!(virt(&["--topology", "racks:2x4"]).is_err(), "8 slots < 15 learners");
        assert!(virt(&["--topology", "racks:0x4"]).is_err());
        assert!(virt(&["--topology", "racks:4x0"]).is_err());
        assert!(virt(&["--topology", "racks:4x4"]).is_ok());
        assert!(virt(&["--topology", "star"]).is_err());
        // uplink needs racks and a sane value
        assert!(virt(&["--uplink-mbps", "50"]).is_err(), "uplink without racks");
        assert!(virt(&["--topology", "racks:4x4", "--uplink-mbps", "-1"]).is_err());
        assert!(virt(&["--topology", "racks:4x4", "--uplink-mbps", "inf"]).is_err());
        assert!(virt(&["--topology", "racks:4x4", "--uplink-mbps", "50"]).is_ok());
        // the models are virtual-time only
        assert!(parse(&["--preset", "x", "--pipeline-depth", "2"]).is_err());
        assert!(parse(&["--preset", "x", "--ctrl-compute-us", "100"]).is_err());
        assert!(parse(&["--preset", "x", "--topology", "racks:4x4"]).is_err());
    }

    #[test]
    fn summary_mentions_key_fields() {
        let cfg = TrainConfig::new("coop_nav_m8");
        let s = cfg.summary();
        assert!(s.contains("coop_nav_m8"));
        assert!(s.contains("N=15"));
        assert!(s.contains("mds"));
    }
}
