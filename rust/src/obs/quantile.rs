//! Streaming quantile estimation: the P² algorithm (Jain & Chlamtac,
//! CACM 1985).
//!
//! A [`P2Quantile`] tracks one quantile of a stream with five markers
//! — O(1) memory and O(1) per observation, no sample storage — which
//! is what lets every sweep cell and every learner carry p50/p90/p99
//! tail telemetry at N = 10 000 without buffering iteration times.
//! For fewer than five observations the exact order statistic is
//! returned, so small runs (and unit tests) are exact.
//!
//! Accuracy is the textbook P² behaviour: within a few percent on
//! smooth distributions at a few hundred samples (pinned loosely by
//! the tests below); the exact-small-n path keeps degenerate cells
//! honest.

/// One streaming quantile (e.g. p = 0.99) via the P² marker method.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    p: f64,
    /// Observations seen.
    n: u64,
    /// Marker heights (estimates of the 5 tracked quantile positions).
    q: [f64; 5],
    /// Actual marker positions, 1-based.
    pos: [f64; 5],
    /// Desired marker positions.
    des: [f64; 5],
    /// Per-observation increments of the desired positions.
    inc: [f64; 5],
    /// The first five observations (exact path until n ≥ 5).
    init: [f64; 5],
}

impl P2Quantile {
    pub fn new(p: f64) -> P2Quantile {
        assert!((0.0..=1.0).contains(&p), "quantile out of range: {p}");
        P2Quantile {
            p,
            n: 0,
            q: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            des: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            inc: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            init: [0.0; 5],
        }
    }

    /// The quantile this sketch tracks.
    pub fn p(&self) -> f64 {
        self.p
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Observe one value (NaN observations are ignored — they would
    /// poison every marker).
    pub fn push(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        if self.n < 5 {
            self.init[self.n as usize] = x;
            self.n += 1;
            if self.n == 5 {
                self.init.sort_by(f64::total_cmp);
                self.q = self.init;
            }
            return;
        }
        self.n += 1;
        // Locate the cell and clamp the extremes.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            if x > self.q[4] {
                self.q[4] = x;
            }
            3
        } else {
            // q[0] <= x < q[4]: the last i in 0..=3 with q[i] <= x.
            let mut k = 0;
            for i in (0..4).rev() {
                if self.q[i] <= x {
                    k = i;
                    break;
                }
            }
            k
        };
        for i in (k + 1)..5 {
            self.pos[i] += 1.0;
        }
        for i in 0..5 {
            self.des[i] += self.inc[i];
        }
        // Adjust the three interior markers toward their desired
        // positions (parabolic when it stays bracketed, else linear).
        for i in 1..4 {
            let d = self.des[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let d = d.signum();
                let qp = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, d)
                };
                self.pos[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let q = &self.q;
        let n = &self.pos;
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = (i as f64 + d) as usize;
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate; NaN when nothing was observed. Exact (nearest
    /// rank) while n < 5.
    pub fn value(&self) -> f64 {
        match self.n {
            0 => f64::NAN,
            n if n < 5 => {
                let n = n as usize;
                let mut v = [0.0; 5];
                v[..n].copy_from_slice(&self.init[..n]);
                v[..n].sort_by(f64::total_cmp);
                // Nearest-rank on the n exact samples.
                let rank = ((self.p * n as f64).ceil() as usize).clamp(1, n);
                v[rank - 1]
            }
            _ => self.q[2],
        }
    }
}

/// The standard trio reported in sweep tables and BENCH json.
#[derive(Clone, Debug)]
pub struct Quantiles {
    q50: P2Quantile,
    q90: P2Quantile,
    q99: P2Quantile,
}

impl Default for Quantiles {
    fn default() -> Quantiles {
        Quantiles::new()
    }
}

impl Quantiles {
    pub fn new() -> Quantiles {
        Quantiles {
            q50: P2Quantile::new(0.50),
            q90: P2Quantile::new(0.90),
            q99: P2Quantile::new(0.99),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.q50.push(x);
        self.q90.push(x);
        self.q99.push(x);
    }

    pub fn count(&self) -> u64 {
        self.q50.count()
    }

    pub fn p50(&self) -> f64 {
        self.q50.value()
    }

    pub fn p90(&self) -> f64 {
        self.q90.value()
    }

    pub fn p99(&self) -> f64 {
        self.q99.value()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn empty_and_small_n_are_exact() {
        let q = Quantiles::new();
        assert!(q.p50().is_nan());
        let mut q = Quantiles::new();
        q.push(3.0);
        q.push(1.0);
        q.push(2.0);
        assert_eq!(q.p50(), 2.0, "exact median of {{1,2,3}}");
        assert_eq!(q.p99(), 3.0, "tail of a tiny sample is its max");
        assert_eq!(q.count(), 3);
        let mut one = P2Quantile::new(0.5);
        one.push(42.0);
        assert_eq!(one.value(), 42.0);
    }

    #[test]
    fn nan_observations_are_ignored() {
        let mut q = Quantiles::new();
        q.push(f64::NAN);
        q.push(5.0);
        assert_eq!(q.count(), 1);
        assert_eq!(q.p50(), 5.0);
    }

    /// P² on a shuffled uniform grid: estimates land within a few
    /// percent of the true quantiles.
    #[test]
    fn tracks_uniform_quantiles() {
        let mut vals: Vec<f64> = (1..=2000).map(|i| i as f64).collect();
        Pcg32::seeded(1234).shuffle(&mut vals);
        let mut q = Quantiles::new();
        for v in &vals {
            q.push(*v);
        }
        assert!((q.p50() - 1000.0).abs() < 60.0, "p50 = {}", q.p50());
        assert!((q.p90() - 1800.0).abs() < 80.0, "p90 = {}", q.p90());
        assert!((q.p99() - 1980.0).abs() < 40.0, "p99 = {}", q.p99());
        // monotone: p50 <= p90 <= p99 on this smooth stream
        assert!(q.p50() <= q.p90() && q.p90() <= q.p99());
    }

    /// A constant stream must report the constant at every quantile.
    #[test]
    fn constant_stream_is_exact() {
        let mut q = Quantiles::new();
        for _ in 0..100 {
            q.push(7.5);
        }
        assert_eq!(q.p50(), 7.5);
        assert_eq!(q.p90(), 7.5);
        assert_eq!(q.p99(), 7.5);
    }

    /// Heavy-tail sanity: with 1% large outliers the p99 must move
    /// toward the outlier mass while p50 stays near the bulk.
    #[test]
    fn tail_separates_from_bulk() {
        let mut q = Quantiles::new();
        let mut rng = Pcg32::seeded(77);
        for i in 0..5000 {
            let bulk = 10.0 + (rng.next_u64() % 1000) as f64 / 1000.0;
            let x = if i % 100 == 99 { 500.0 } else { bulk };
            q.push(x);
        }
        assert!(q.p50() < 12.0, "p50 = {}", q.p50());
        assert!(q.p99() > 50.0, "p99 must feel the 1% outliers: {}", q.p99());
    }
}
