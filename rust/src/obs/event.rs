//! The structured event vocabulary of the coded training loop.
//!
//! One enum covers the controller hot loop (Alg. 1 lines 9-15) and the
//! transport beneath it; every variant carries plain integers so
//! recording is allocation-free. Timestamps live outside the event
//! ([`TracedEvent::at`]) and come from the transport's
//! [`crate::sim::ClockRef`], so a virtual-time trace and a wall-clock
//! trace have identical structure.

use std::time::Duration;

/// How the controller classified a learner reply (`collect`, Alg. 1
/// lines 10-13).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Disposition {
    /// Accepted: folded into the decodable prefix.
    Used,
    /// Reply for a *future* iteration or an out-of-range learner id
    /// (protocol confusion; should not happen).
    Stale,
    /// Reply for an already-completed iteration — the result raced the
    /// ack and its work is wasted (the real-transport twin of the sim's
    /// cancelled events).
    PostDecodable,
    /// Reply from a learner whose assignment row is all-zero (never
    /// tasked; contributes nothing to decodability).
    ZeroWorkload,
    /// Second reply from a learner this iteration.
    Duplicate,
    /// Parseable frame with a wrong-length result vector — dropped as
    /// an erasure.
    Malformed,
}

impl Disposition {
    pub fn name(self) -> &'static str {
        match self {
            Disposition::Used => "used",
            Disposition::Stale => "stale",
            Disposition::PostDecodable => "post_decodable",
            Disposition::ZeroWorkload => "zero_workload",
            Disposition::Duplicate => "duplicate",
            Disposition::Malformed => "malformed",
        }
    }

    /// Dispositions whose bytes/compute count as wasted work.
    pub fn is_waste(self) -> bool {
        matches!(self, Disposition::PostDecodable | Disposition::Duplicate | Disposition::Malformed)
    }
}

/// One hot-loop occurrence. Byte counts are exact wire lengths
/// (`transport::msg::{task_header_wire_len, result_wire_len}`,
/// `TaskBody::wire_len`), identical across transports.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// `run_iteration(iter)` entered.
    IterStart { iter: u64 },
    /// The broadcast-shared Task body for `iter` (encoded at most once;
    /// `bytes` is its exact wire length).
    BroadcastBody { iter: u64, bytes: u64 },
    /// Per-learner Task header sent (`bytes` = header wire length; the
    /// body bytes ride on [`Event::BroadcastBody`]).
    TaskSent { iter: u64, learner: u32, bytes: u64 },
    /// The disturbance model slowed `learner` by `delay_ns` this
    /// iteration (§V-C injector or trace replay).
    StragglerInjected { iter: u64, learner: u32, delay_ns: u64 },
    /// A learner reply reached `collect` and was classified. `iter` is
    /// the *result's* iteration (≠ current for stale/post-decodable).
    ResultArrival {
        iter: u64,
        learner: u32,
        disposition: Disposition,
        bytes: u64,
        compute_ns: u64,
    },
    /// An accepted arrival advanced the incremental rank to `rank`.
    RankAdvance { iter: u64, rank: u32 },
    /// The received prefix reached rank M. `front_ns` is the
    /// decodability front: time from the first used arrival to this
    /// event.
    DecodableAt { iter: u64, front_ns: u64 },
    /// θ' recovered. `cache_hit` = the decode plan came from the LRU
    /// cache (no fresh factorization).
    DecodeDone { iter: u64, method: &'static str, cache_hit: bool },
    /// `run_iteration(iter)` returned.
    IterEnd { iter: u64 },
    /// Sim transport: an in-flight result was cancelled by the
    /// iteration's ack (lazy heap deletion) — pure wasted work.
    ResultCancelled { iter: u64, learner: u32, bytes: u64, compute_ns: u64 },
    /// Transport level: a result frame crossed the wire (TCP reader /
    /// sim delivery), before the controller classified it.
    FrameRecv { learner: u32, bytes: u64 },
    /// Data-plane buffer-pool counters sampled at an iteration end.
    PoolSample { hits: u64, misses: u64, resident: u64 },
    /// Network-model transfer counters sampled at an iteration end.
    NetSample { broadcast_ns: u64, return_ns: u64 },
    /// Fault injection crashed `learner` (`down_ns` = drawn downtime;
    /// `None` = permanent). Recorded by the sim transport when the
    /// directive is applied.
    CrashInjected { iter: u64, learner: u32, down_ns: Option<u64> },
    /// The failure detector's strike count on `learner` crossed the
    /// suspicion threshold (`misses` consecutive corroborated losses).
    LearnerSuspected { iter: u64, learner: u32, misses: u32 },
    /// The failure detector declared `learner` dead; membership remap
    /// follows.
    LearnerDeclaredDead { iter: u64, learner: u32, misses: u32 },
    /// Assignment rows were remapped onto `survivors` learners after
    /// `dead` cumulative deaths; the code is rebuilt over the
    /// survivor set.
    MembershipRemap { iter: u64, survivors: u32, dead: u32 },
    /// The iteration could not reach rank M on live learners
    /// (`survivors` alive, rank stuck at `rank`); `fallback` = the run
    /// continues via uncoded fallback, else it terminates with a
    /// structured fault error.
    DegradedDecode { iter: u64, survivors: u32, rank: u32, fallback: bool },
    /// A successor [`crate::coding::CodingPlan`] was installed (adaptive
    /// scheme switch or membership remap): `epoch` is the new plan's
    /// version, `rows` its live row count. Results on the wire that
    /// were encoded under an earlier epoch are classified stale from
    /// here on.
    PlanSwitch { iter: u64, epoch: u16, scheme: &'static str, rows: u32 },
    /// The adaptive selector's obs-fed estimate after this iteration's
    /// telemetry: expected stragglers (milli-units, so 2500 = 2.5
    /// learners), the avoidable delay, and wasted compute per
    /// decodable iteration.
    EstimateUpdate { iter: u64, k_milli: u64, delay_ns: u64, waste_ns_per_iter: u64 },
    /// Fault injection corrupted `learner`'s result this iteration
    /// (delivered perturbed, not dropped). Recorded by the sim
    /// transport when the directive is applied.
    CorruptionInjected { iter: u64, learner: u32, mode: &'static str },
    /// The verified decoder's residual parity check failed.
    /// `identified` = the error-locating pass pinned the corrupted row
    /// to `learner`; when false (not enough surplus to locate, or no
    /// single row explains the misfit) `learner` is `u32::MAX`.
    VerifyFailed { iter: u64, learner: u32, identified: bool },
    /// A learner identified as corrupt crossed the death threshold on
    /// corruption strikes and was quarantined: membership remap
    /// excludes it from the successor plan.
    LearnerQuarantined { iter: u64, learner: u32 },
    /// Depth-2 pipelining could not fully hide the controller prelude:
    /// `stall_ns` of `--ctrl-compute-us` remained after crediting the
    /// previous iteration's collect+decode window.
    PipelineStall { iter: u64, stall_ns: u64 },
    /// Sharded collect: an arrival accepted into shard `shard`'s local
    /// tracker advanced the *global* rank to `rank` through the
    /// hierarchical combine.
    ShardMerge { iter: u64, shard: u32, rank: u32 },
    /// A result queued `queued_ns` behind busy rack-uplink/controller-
    /// ingress links before delivery (racked-topology incast).
    IngressQueued { iter: u64, learner: u32, queued_ns: u64 },
}

impl Event {
    /// Stable snake_case tag used by the JSONL exporter.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::IterStart { .. } => "iter_start",
            Event::BroadcastBody { .. } => "broadcast_body",
            Event::TaskSent { .. } => "task_sent",
            Event::StragglerInjected { .. } => "straggler_injected",
            Event::ResultArrival { .. } => "result_arrival",
            Event::RankAdvance { .. } => "rank_advance",
            Event::DecodableAt { .. } => "decodable_at",
            Event::DecodeDone { .. } => "decode_done",
            Event::IterEnd { .. } => "iter_end",
            Event::ResultCancelled { .. } => "result_cancelled",
            Event::FrameRecv { .. } => "frame_recv",
            Event::PoolSample { .. } => "pool_sample",
            Event::NetSample { .. } => "net_sample",
            Event::CrashInjected { .. } => "crash_injected",
            Event::LearnerSuspected { .. } => "learner_suspected",
            Event::LearnerDeclaredDead { .. } => "learner_declared_dead",
            Event::MembershipRemap { .. } => "membership_remap",
            Event::DegradedDecode { .. } => "degraded_decode",
            Event::PlanSwitch { .. } => "plan_switch",
            Event::EstimateUpdate { .. } => "estimate_update",
            Event::CorruptionInjected { .. } => "corruption_injected",
            Event::VerifyFailed { .. } => "verify_failed",
            Event::LearnerQuarantined { .. } => "learner_quarantined",
            Event::PipelineStall { .. } => "pipeline_stall",
            Event::ShardMerge { .. } => "shard_merge",
            Event::IngressQueued { .. } => "ingress_queued",
        }
    }
}

/// An [`Event`] stamped with its clock time (real or virtual).
#[derive(Clone, Debug, PartialEq)]
pub struct TracedEvent {
    /// Time on the recording [`crate::sim::ClockRef`]'s epoch.
    pub at: Duration,
    pub event: Event,
}
