//! The bounded [`EventLog`] ring buffer and the [`Tracer`] handle the
//! hot loop records through.
//!
//! A [`Tracer`] is shared (`Arc`) between the controller and its
//! transport so both sides of the wire append to one timeline. The
//! enabled flag is fixed at construction: the disabled tracer's
//! [`Tracer::record`] is a single branch on a plain bool — it never
//! reads the clock, never takes the lock, and never even constructs
//! the event (callers pass a closure), which is what keeps untraced
//! runs bit-identical to the pre-tracing code path.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::sim::{real_clock, ClockRef};

use super::event::{Event, TracedEvent};

/// Default ring capacity: ~64k events ≈ hundreds of 15-learner
/// iterations; old events are dropped (and counted) rather than
/// growing without bound at N = 10 000.
pub const DEFAULT_EVENT_CAP: usize = 1 << 16;

/// Bounded ring buffer of timestamped events.
#[derive(Debug, Default)]
pub struct EventLog {
    cap: usize,
    events: VecDeque<TracedEvent>,
    dropped: u64,
}

impl EventLog {
    pub fn new(cap: usize) -> EventLog {
        EventLog { cap, events: VecDeque::new(), dropped: 0 }
    }

    /// Append, evicting the oldest event when full.
    pub fn push(&mut self, ev: TracedEvent) {
        if self.cap == 0 {
            self.dropped += 1;
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted (or refused by a zero-capacity log) so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Copy the buffered events out, oldest first.
    pub fn snapshot(&self) -> Vec<TracedEvent> {
        self.events.iter().cloned().collect()
    }
}

/// Shared recording handle stamped off a [`ClockRef`].
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    clock: ClockRef,
    log: Mutex<EventLog>,
}

impl Tracer {
    /// The no-op tracer: `record` is a branch and nothing else.
    pub fn disabled() -> Arc<Tracer> {
        Arc::new(Tracer {
            enabled: false,
            clock: real_clock(),
            log: Mutex::new(EventLog::new(0)),
        })
    }

    /// A recording tracer on `clock` (the transport's time domain, so
    /// virtual runs produce virtual-time traces).
    pub fn enabled(clock: ClockRef, cap: usize) -> Arc<Tracer> {
        Arc::new(Tracer { enabled: true, clock, log: Mutex::new(EventLog::new(cap)) })
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record the event built by `ev`, stamped with the clock's now.
    /// When disabled the closure is never called.
    #[inline]
    pub fn record(&self, ev: impl FnOnce() -> Event) {
        if !self.enabled {
            return;
        }
        let at = self.clock.now();
        let mut log = self.log.lock().expect("event log poisoned");
        log.push(TracedEvent { at, event: ev() });
    }

    /// Copy the buffered events out, oldest first.
    pub fn snapshot(&self) -> Vec<TracedEvent> {
        self.log.lock().expect("event log poisoned").snapshot()
    }

    /// Events lost to the ring bound so far.
    pub fn dropped(&self) -> u64 {
        self.log.lock().expect("event log poisoned").dropped()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn ring_bounds_and_counts_evictions() {
        let mut log = EventLog::new(3);
        for i in 0..5u64 {
            log.push(TracedEvent {
                at: Duration::from_nanos(i),
                event: Event::IterStart { iter: i },
            });
        }
        assert_eq!(log.len(), 3);
        assert_eq!(log.dropped(), 2);
        let evs = log.snapshot();
        assert_eq!(evs[0].event, Event::IterStart { iter: 2 }, "oldest events evicted first");
        assert_eq!(evs[2].event, Event::IterStart { iter: 4 });
    }

    #[test]
    fn disabled_tracer_records_nothing_and_skips_the_closure() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        let mut called = false;
        t.record(|| {
            called = true;
            Event::IterStart { iter: 0 }
        });
        assert!(!called, "disabled tracer must not construct events");
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn enabled_tracer_stamps_off_the_given_clock() {
        let vc = crate::sim::VirtualClock::shared();
        vc.advance_to(Duration::from_millis(7));
        let clock: ClockRef = vc.clone();
        let t = Tracer::enabled(clock, 16);
        assert!(t.is_enabled());
        t.record(|| Event::IterStart { iter: 1 });
        vc.advance_to(Duration::from_millis(9));
        t.record(|| Event::IterEnd { iter: 1 });
        let evs = t.snapshot();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].at, Duration::from_millis(7));
        assert_eq!(evs[1].at, Duration::from_millis(9));
        assert_eq!(t.dropped(), 0);
    }
}
