//! Derived analytics: per-learner straggler attribution, the
//! decodability front, and wasted-work accounting.
//!
//! These run **always-on** in the controller (unlike event tracing):
//! they are pure accumulators over values the collect loop already
//! has — no RNG, no timing side effects — so enabling them cannot
//! perturb a virtual run (the bit-identity test in
//! `tests/obs_integration.rs` covers the traced case, which subsumes
//! this one).
//!
//! * [`Attribution`] answers *which learner costs us the tail*: per
//!   learner, a quartile arrival-rank histogram, P² latency quantiles
//!   of its used arrivals, how often its arrival was the one that made
//!   the prefix decodable, and how many of its arrivals happened while
//!   the disturbance model had injected a delay into it
//!   (injected-vs-organic split).
//! * The decodability **front** is the time from an iteration's first
//!   used arrival until rank M — the window the code's redundancy has
//!   to cover; its p99 is the quantity the scheme comparison in the
//!   Karakus et al. survey turns on.
//! * [`WasteStats`] counts the results whose bytes/compute bought
//!   nothing: post-decodable and malformed arrivals on real
//!   transports, ack-cancelled in-flight results on the sim transport.

use std::time::Duration;

use crate::metrics::table::Table;

use super::quantile::Quantiles;

/// Bytes and compute-seconds spent on results that were never used.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WasteStats {
    /// Results wasted (cancelled, post-decodable, duplicate, malformed).
    pub results: u64,
    /// Exact wire bytes those results occupied (or would have).
    pub bytes: u64,
    /// Learner compute spent producing them, in nanoseconds.
    pub compute_ns: u64,
}

impl WasteStats {
    pub fn add(&mut self, bytes: u64, compute_ns: u64) {
        self.results += 1;
        self.bytes += bytes;
        self.compute_ns += compute_ns;
    }

    pub fn merge(&mut self, other: &WasteStats) {
        self.results += other.results;
        self.bytes += other.bytes;
        self.compute_ns += other.compute_ns;
    }

    pub fn compute_secs(&self) -> f64 {
        self.compute_ns as f64 / 1e9
    }
}

/// Per-learner accumulators (see [`Attribution`]).
#[derive(Clone, Debug, Default)]
struct LearnerAttr {
    /// Used arrivals.
    arrivals: u64,
    /// Sum of 1-based arrival ranks of those arrivals.
    rank_sum: u64,
    /// Arrival-rank histogram over quartiles of the tasked count:
    /// bucket q holds arrivals whose rank fell in the q-th quarter of
    /// that iteration's tasked learners.
    rank_hist: [u64; 4],
    /// Times this learner's arrival completed rank M (the decisive,
    /// iteration-ending arrival).
    decisive: u64,
    /// Used arrivals that happened while the disturbance model had
    /// injected a delay into this learner.
    injected: u64,
    /// Latency quantiles of used arrivals (collect start → arrival).
    latency: Quantiles,
}

/// Compact per-cell attribution summary carried into sweep tables and
/// BENCH json (the full per-learner table is printed for single-cell
/// deep dives and available via [`Attribution::render_table`]).
#[derive(Clone, Copy, Debug)]
pub struct AttrSummary {
    /// Decodability front (first used arrival → rank M), seconds.
    pub front_p50_s: f64,
    pub front_p99_s: f64,
    /// The learner with the worst p99 arrival latency, if any arrived.
    pub tail_learner: Option<u32>,
    /// That learner's p99 arrival latency, seconds (0 when none).
    pub tail_p99_s: f64,
    /// Fraction of used arrivals that came from learners with an
    /// injected delay that iteration (the injected-vs-organic split;
    /// the remainder of the tail is organic).
    pub injected_share: f64,
}

impl Default for AttrSummary {
    fn default() -> AttrSummary {
        AttrSummary {
            front_p50_s: 0.0,
            front_p99_s: 0.0,
            tail_learner: None,
            tail_p99_s: 0.0,
            injected_share: 0.0,
        }
    }
}

/// Straggler attribution over a run: who arrives late, who decides
/// iterations, and how wide the decodability front is.
#[derive(Clone, Debug)]
pub struct Attribution {
    learners: Vec<LearnerAttr>,
    front: Quantiles,
    /// Used arrivals observed in total.
    arrivals: u64,
    /// … of which from injected-delay learners.
    injected: u64,
    /// Iterations that reached decodability.
    iters: u64,
}

impl Attribution {
    pub fn new(n_learners: usize) -> Attribution {
        Attribution {
            learners: vec![LearnerAttr::default(); n_learners],
            front: Quantiles::new(),
            arrivals: 0,
            injected: 0,
            iters: 0,
        }
    }

    /// Record a used arrival: `rank` is 1-based among this iteration's
    /// used arrivals, `tasked` the number of tasked learners,
    /// `latency` the time since the collect phase began, `injected`
    /// whether the disturbance plan delayed this learner.
    pub fn observe_arrival(
        &mut self,
        learner: usize,
        rank: usize,
        tasked: usize,
        latency: Duration,
        injected: bool,
    ) {
        let Some(l) = self.learners.get_mut(learner) else { return };
        l.arrivals += 1;
        l.rank_sum += rank as u64;
        let quarter = if tasked > 0 { (4 * (rank - 1) / tasked).min(3) } else { 0 };
        l.rank_hist[quarter] += 1;
        l.latency.push(latency.as_secs_f64());
        self.arrivals += 1;
        if injected {
            l.injected += 1;
            self.injected += 1;
        }
    }

    /// Record that `learner`'s arrival completed rank M, `front` after
    /// the iteration's first used arrival.
    pub fn observe_decodable(&mut self, learner: usize, front: Duration) {
        if let Some(l) = self.learners.get_mut(learner) {
            l.decisive += 1;
        }
        self.front.push(front.as_secs_f64());
        self.iters += 1;
    }

    /// Iterations that reached decodability.
    pub fn iters(&self) -> u64 {
        self.iters
    }

    /// Used arrivals recorded for `learner` (0 when out of range) —
    /// read-only view for the failure detector and timeout
    /// diagnostics; no new counters.
    pub fn arrivals_of(&self, learner: usize) -> u64 {
        self.learners.get(learner).map_or(0, |l| l.arrivals)
    }

    /// `(p50, p99)` arrival latency of `learner`'s used arrivals in
    /// seconds, `None` until it has arrived at least once.
    pub fn latency_of(&self, learner: usize) -> Option<(f64, f64)> {
        let l = self.learners.get(learner).filter(|l| l.arrivals > 0)?;
        Some((Self::finite(l.latency.p50()), Self::finite(l.latency.p99())))
    }

    /// One-line arrival attribution for `learner`, used by the collect
    /// timeout error and detector events ("12 arrivals, p99 38.2ms" /
    /// "never arrived").
    pub fn describe(&self, learner: usize) -> String {
        match self.latency_of(learner) {
            Some((_, p99)) => {
                format!("{} arrivals, p99 {:.1}ms", self.arrivals_of(learner), p99 * 1e3)
            }
            None => "never arrived".to_string(),
        }
    }

    /// Decodability-front quantiles (seconds).
    pub fn front(&self) -> &Quantiles {
        &self.front
    }

    fn finite(x: f64) -> f64 {
        if x.is_finite() {
            x
        } else {
            0.0
        }
    }

    /// Compact summary for sweep cells / BENCH json.
    pub fn summary(&self) -> AttrSummary {
        let mut tail: Option<(u32, f64)> = None;
        for (j, l) in self.learners.iter().enumerate() {
            if l.arrivals == 0 {
                continue;
            }
            let p99 = l.latency.p99();
            if p99.is_finite() && tail.map(|(_, t)| p99 > t).unwrap_or(true) {
                tail = Some((j as u32, p99));
            }
        }
        AttrSummary {
            front_p50_s: Self::finite(self.front.p50()),
            front_p99_s: Self::finite(self.front.p99()),
            tail_learner: tail.map(|(j, _)| j),
            tail_p99_s: tail.map(|(_, t)| t).unwrap_or(0.0),
            injected_share: if self.arrivals > 0 {
                self.injected as f64 / self.arrivals as f64
            } else {
                0.0
            },
        }
    }

    /// Per-learner attribution table, worst p99 latency first, at most
    /// `top` rows (learners that never arrived are skipped).
    pub fn render_table(&self, top: usize) -> String {
        let mut order: Vec<usize> = (0..self.learners.len())
            .filter(|&j| self.learners[j].arrivals > 0)
            .collect();
        order.sort_by(|&a, &b| {
            let (la, lb) = (&self.learners[a], &self.learners[b]);
            lb.latency.p99().total_cmp(&la.latency.p99()).then(a.cmp(&b))
        });
        let mut t = Table::new(&[
            "learner", "used", "mean_rank", "rank_hist", "p50_ms", "p99_ms", "injected",
            "decisive",
        ]);
        for &j in order.iter().take(top) {
            let l = &self.learners[j];
            t.row(&[
                j.to_string(),
                l.arrivals.to_string(),
                format!("{:.1}", l.rank_sum as f64 / l.arrivals as f64),
                format!(
                    "{}|{}|{}|{}",
                    l.rank_hist[0], l.rank_hist[1], l.rank_hist[2], l.rank_hist[3]
                ),
                format!("{:.2}", Self::finite(l.latency.p50()) * 1e3),
                format!("{:.2}", Self::finite(l.latency.p99()) * 1e3),
                format!("{:.0}%", 100.0 * l.injected as f64 / l.arrivals as f64),
                l.decisive.to_string(),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waste_accumulates_and_merges() {
        let mut a = WasteStats::default();
        a.add(100, 2_000_000_000);
        a.add(50, 500_000_000);
        let mut b = WasteStats::default();
        b.add(10, 1_000_000_000);
        a.merge(&b);
        assert_eq!(a.results, 3);
        assert_eq!(a.bytes, 160);
        assert!((a.compute_secs() - 3.5).abs() < 1e-12);
        assert_eq!(WasteStats::default(), WasteStats { results: 0, bytes: 0, compute_ns: 0 });
    }

    #[test]
    fn attribution_tracks_ranks_fronts_and_splits() {
        let mut attr = Attribution::new(3);
        // Two iterations over 3 tasked learners: learner 2 is always
        // last and injected; learner 0 always first.
        for _ in 0..2 {
            attr.observe_arrival(0, 1, 3, Duration::from_millis(1), false);
            attr.observe_arrival(1, 2, 3, Duration::from_millis(2), false);
            attr.observe_arrival(2, 3, 3, Duration::from_millis(30), true);
            attr.observe_decodable(2, Duration::from_millis(29));
        }
        assert_eq!(attr.iters(), 2);
        let s = attr.summary();
        assert_eq!(s.tail_learner, Some(2), "worst p99 latency must name learner 2");
        assert!((s.tail_p99_s - 0.030).abs() < 1e-9);
        assert!((s.injected_share - 2.0 / 6.0).abs() < 1e-12);
        assert!((s.front_p50_s - 0.029).abs() < 1e-9);
        let table = attr.render_table(10);
        assert!(table.contains("learner"), "{table}");
        // learner 2: decisive both times, 100% injected
        let row2 = table.lines().find(|l| l.trim_start().starts_with('2')).unwrap();
        assert!(row2.contains("100%"), "{row2}");
        assert!(row2.contains('2'), "{row2}");
    }

    #[test]
    fn empty_attribution_yields_a_null_summary() {
        let attr = Attribution::new(4);
        let s = attr.summary();
        assert_eq!(s.tail_learner, None);
        assert_eq!(s.front_p99_s, 0.0);
        assert_eq!(s.injected_share, 0.0);
        assert!(attr.render_table(5).contains("learner"));
    }

    #[test]
    fn out_of_range_learners_are_ignored() {
        let mut attr = Attribution::new(2);
        attr.observe_arrival(9, 1, 2, Duration::ZERO, false);
        assert_eq!(attr.summary().tail_learner, None);
    }

    #[test]
    fn per_learner_accessors_expose_arrivals_and_tails() {
        let mut attr = Attribution::new(2);
        assert_eq!(attr.arrivals_of(0), 0);
        assert_eq!(attr.latency_of(0), None);
        assert_eq!(attr.describe(0), "never arrived");
        attr.observe_arrival(0, 1, 2, Duration::from_millis(5), false);
        attr.observe_arrival(0, 1, 2, Duration::from_millis(7), false);
        assert_eq!(attr.arrivals_of(0), 2);
        let (p50, p99) = attr.latency_of(0).unwrap();
        assert!(p50 > 0.0 && p99 >= p50);
        assert!(attr.describe(0).starts_with("2 arrivals"), "{}", attr.describe(0));
        // out of range stays inert
        assert_eq!(attr.arrivals_of(9), 0);
        assert_eq!(attr.describe(9), "never arrived");
    }
}
