//! Tiny leveled stderr logger (no `log` crate).
//!
//! Level comes from the `CODED_MARL_LOG` environment variable
//! (`error|warn|info|debug|off`), read once on first use; the default
//! is `warn` so operational warnings (bad frames, unreachable
//! learners, backend failures) stay visible exactly as the old
//! unconditional `eprintln!` calls were. A disabled call site costs
//! one relaxed atomic load and a branch — `format_args!` captures
//! references lazily, so nothing is formatted unless the level is on.
//!
//! CLI *table* output (sweep tables, bench summaries) stays on plain
//! `println!` — it is the program's product, not its diagnostics.
//!
//! `--verbose` raises the process level to `info` via
//! [`set_max_level`] (an explicit env var still wins: set_max_level
//! never lowers an env-configured level).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered: a level is emitted when it is ≤ the
/// configured maximum.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn from_env(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "0" => Some(Level::Off),
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" | "trace" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// Sentinel: level not yet read from the environment.
const UNINIT: u8 = u8::MAX;

static MAX_LEVEL: AtomicU8 = AtomicU8::new(UNINIT);
/// Whether the level came from an explicit `CODED_MARL_LOG` (which
/// then wins over programmatic raises like `--verbose`).
static FROM_ENV: AtomicU8 = AtomicU8::new(0);

#[cold]
fn init() -> u8 {
    let (lvl, explicit) = match std::env::var("CODED_MARL_LOG") {
        Ok(v) => match Level::from_env(&v) {
            Some(l) => (l as u8, 1),
            None => {
                eprintln!("[warn] CODED_MARL_LOG={v:?} not recognized; using warn");
                (Level::Warn as u8, 0)
            }
        },
        Err(_) => (Level::Warn as u8, 0),
    };
    FROM_ENV.store(explicit, Ordering::Relaxed);
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Is `level` currently emitted?
#[inline]
pub fn enabled(level: Level) -> bool {
    let max = MAX_LEVEL.load(Ordering::Relaxed);
    let max = if max == UNINIT { init() } else { max };
    (level as u8) <= max
}

/// Raise the maximum level programmatically (e.g. `--verbose` ⇒
/// `Info`). Never lowers a level set explicitly via `CODED_MARL_LOG`,
/// and never lowers the current level.
pub fn set_max_level(level: Level) {
    // Force env init first so FROM_ENV is meaningful.
    let current = {
        let m = MAX_LEVEL.load(Ordering::Relaxed);
        if m == UNINIT {
            init()
        } else {
            m
        }
    };
    if FROM_ENV.load(Ordering::Relaxed) == 1 {
        return;
    }
    if (level as u8) > current {
        MAX_LEVEL.store(level as u8, Ordering::Relaxed);
    }
}

/// Emit one log line (call sites go through the `log_*!` macros, which
/// check [`enabled`] first).
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    eprintln!("[{}] {}", level.name(), args);
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::Level::Error) {
            $crate::obs::log::emit($crate::obs::Level::Error, format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::Level::Warn) {
            $crate::obs::log::emit($crate::obs::Level::Warn, format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::Level::Info) {
            $crate::obs::log::emit($crate::obs::Level::Info, format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::Level::Debug) {
            $crate::obs::log::emit($crate::obs::Level::Debug, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_parse() {
        assert!(Level::Error < Level::Debug);
        assert_eq!(Level::from_env("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_env(" debug "), Some(Level::Debug));
        assert_eq!(Level::from_env("off"), Some(Level::Off));
        assert_eq!(Level::from_env("???"), None);
    }

    #[test]
    fn set_max_level_only_raises() {
        // Whatever the env says, raising to Debug must enable Info…
        set_max_level(Level::Debug);
        if FROM_ENV.load(Ordering::Relaxed) == 0 {
            assert!(enabled(Level::Info));
            // …and a later lower request must not lower it back.
            set_max_level(Level::Error);
            assert!(enabled(Level::Info));
        }
    }
}
