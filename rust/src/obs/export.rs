//! Trace exporters: JSONL (one event object per line) and the Chrome
//! trace-event format (Perfetto / `chrome://tracing`).
//!
//! Hand-rolled writers following the `runtime/json.rs` conventions —
//! no serde. The Chrome trace lays one lane (`tid`) per learner plus
//! lane 0 for the controller: iterations are complete (`"X"`) spans on
//! the controller lane, each learner's task (send → arrival or
//! cancellation) is a span on its own lane, rank progress is a counter
//! track, and stragglers / decodability / decode outcomes are instant
//! events. Timestamps are microseconds on the recording clock (virtual
//! time for sim runs — Perfetto renders it like any other timeline).

use std::collections::HashMap;
use std::io::Write;
use std::path::Path;
use std::time::Duration;

use super::event::{Event, TracedEvent};

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn us(at: Duration) -> String {
    format!("{:.3}", at.as_secs_f64() * 1e6)
}

/// One event per line, flat fields, `t_ns` on the recording clock.
pub fn jsonl(events: &[TracedEvent]) -> String {
    let mut out = String::new();
    for te in events {
        let t = te.at.as_nanos();
        let body = match &te.event {
            Event::IterStart { iter } => format!("\"iter\":{iter}"),
            Event::BroadcastBody { iter, bytes } => {
                format!("\"iter\":{iter},\"bytes\":{bytes}")
            }
            Event::TaskSent { iter, learner, bytes } => {
                format!("\"iter\":{iter},\"learner\":{learner},\"bytes\":{bytes}")
            }
            Event::StragglerInjected { iter, learner, delay_ns } => {
                format!("\"iter\":{iter},\"learner\":{learner},\"delay_ns\":{delay_ns}")
            }
            Event::ResultArrival { iter, learner, disposition, bytes, compute_ns } => format!(
                "\"iter\":{iter},\"learner\":{learner},\"disposition\":\"{}\",\"bytes\":{bytes},\"compute_ns\":{compute_ns}",
                disposition.name()
            ),
            Event::RankAdvance { iter, rank } => format!("\"iter\":{iter},\"rank\":{rank}"),
            Event::DecodableAt { iter, front_ns } => {
                format!("\"iter\":{iter},\"front_ns\":{front_ns}")
            }
            Event::DecodeDone { iter, method, cache_hit } => format!(
                "\"iter\":{iter},\"method\":\"{}\",\"cache_hit\":{cache_hit}",
                esc(method)
            ),
            Event::IterEnd { iter } => format!("\"iter\":{iter}"),
            Event::ResultCancelled { iter, learner, bytes, compute_ns } => format!(
                "\"iter\":{iter},\"learner\":{learner},\"bytes\":{bytes},\"compute_ns\":{compute_ns}"
            ),
            Event::FrameRecv { learner, bytes } => {
                format!("\"learner\":{learner},\"bytes\":{bytes}")
            }
            Event::PoolSample { hits, misses, resident } => {
                format!("\"hits\":{hits},\"misses\":{misses},\"resident\":{resident}")
            }
            Event::NetSample { broadcast_ns, return_ns } => {
                format!("\"broadcast_ns\":{broadcast_ns},\"return_ns\":{return_ns}")
            }
            Event::CrashInjected { iter, learner, down_ns } => match down_ns {
                Some(ns) => {
                    format!("\"iter\":{iter},\"learner\":{learner},\"down_ns\":{ns}")
                }
                None => format!("\"iter\":{iter},\"learner\":{learner},\"down_ns\":null"),
            },
            Event::LearnerSuspected { iter, learner, misses } => {
                format!("\"iter\":{iter},\"learner\":{learner},\"misses\":{misses}")
            }
            Event::LearnerDeclaredDead { iter, learner, misses } => {
                format!("\"iter\":{iter},\"learner\":{learner},\"misses\":{misses}")
            }
            Event::MembershipRemap { iter, survivors, dead } => {
                format!("\"iter\":{iter},\"survivors\":{survivors},\"dead\":{dead}")
            }
            Event::DegradedDecode { iter, survivors, rank, fallback } => format!(
                "\"iter\":{iter},\"survivors\":{survivors},\"rank\":{rank},\"fallback\":{fallback}"
            ),
            Event::PlanSwitch { iter, epoch, scheme, rows } => format!(
                "\"iter\":{iter},\"epoch\":{epoch},\"scheme\":\"{}\",\"rows\":{rows}",
                esc(scheme)
            ),
            Event::EstimateUpdate { iter, k_milli, delay_ns, waste_ns_per_iter } => format!(
                "\"iter\":{iter},\"k_milli\":{k_milli},\"delay_ns\":{delay_ns},\"waste_ns_per_iter\":{waste_ns_per_iter}"
            ),
            Event::CorruptionInjected { iter, learner, mode } => format!(
                "\"iter\":{iter},\"learner\":{learner},\"mode\":\"{}\"",
                esc(mode)
            ),
            Event::VerifyFailed { iter, learner, identified } => format!(
                "\"iter\":{iter},\"learner\":{learner},\"identified\":{identified}"
            ),
            Event::LearnerQuarantined { iter, learner } => {
                format!("\"iter\":{iter},\"learner\":{learner}")
            }
            Event::PipelineStall { iter, stall_ns } => {
                format!("\"iter\":{iter},\"stall_ns\":{stall_ns}")
            }
            Event::ShardMerge { iter, shard, rank } => {
                format!("\"iter\":{iter},\"shard\":{shard},\"rank\":{rank}")
            }
            Event::IngressQueued { iter, learner, queued_ns } => {
                format!("\"iter\":{iter},\"learner\":{learner},\"queued_ns\":{queued_ns}")
            }
        };
        out.push_str(&format!("{{\"t_ns\":{t},\"ev\":\"{}\",{body}}}\n", te.event.kind()));
    }
    out
}

pub fn write_jsonl(events: &[TracedEvent], path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(jsonl(events).as_bytes())
}

/// Lane id for a learner (lane 0 is the controller).
fn lane(learner: u32) -> u32 {
    learner + 1
}

/// Render the Chrome trace-event JSON for `events` over `n_learners`
/// lanes.
pub fn chrome_trace(events: &[TracedEvent], n_learners: usize) -> String {
    let mut evs: Vec<String> = Vec::new();
    let meta = |name: &str, tid: u32, label: &str| {
        format!(
            "{{\"name\":\"{name}\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\"args\":{{\"name\":\"{}\"}}}}",
            esc(label)
        )
    };
    evs.push("{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"args\":{\"name\":\"coded-marl\"}}".into());
    evs.push(meta("thread_name", 0, "controller"));
    evs.push(meta("thread_sort_index", 0, "controller"));
    for j in 0..n_learners {
        evs.push(meta("thread_name", lane(j as u32), &format!("learner {j}")));
    }

    let span = |name: &str, tid: u32, start: Duration, end: Duration, args: String| {
        let dur = (end.saturating_sub(start)).as_secs_f64() * 1e6;
        format!(
            "{{\"name\":\"{name}\",\"ph\":\"X\",\"pid\":1,\"tid\":{tid},\"ts\":{},\"dur\":{dur:.3},\"args\":{{{args}}}}}",
            us(start)
        )
    };
    let instant = |name: &str, tid: u32, at: Duration, args: String| {
        format!(
            "{{\"name\":\"{name}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":1,\"tid\":{tid},\"ts\":{},\"args\":{{{args}}}}}",
            us(at)
        )
    };
    let counter = |name: &str, at: Duration, args: String| {
        format!(
            "{{\"name\":\"{name}\",\"ph\":\"C\",\"pid\":1,\"ts\":{},\"args\":{{{args}}}}}",
            us(at)
        )
    };

    let mut open_iter: Option<(u64, Duration)> = None;
    let mut open_task: HashMap<(u64, u32), Duration> = HashMap::new();
    for te in events {
        let at = te.at;
        match &te.event {
            Event::IterStart { iter } => open_iter = Some((*iter, at)),
            Event::IterEnd { iter } => {
                if let Some((i0, t0)) = open_iter.take() {
                    if i0 == *iter {
                        evs.push(span("iter", 0, t0, at, format!("\"iter\":{iter}")));
                    }
                }
            }
            Event::BroadcastBody { iter, bytes } => evs.push(instant(
                "broadcast",
                0,
                at,
                format!("\"iter\":{iter},\"bytes\":{bytes}"),
            )),
            Event::TaskSent { iter, learner, .. } => {
                open_task.insert((*iter, *learner), at);
            }
            Event::StragglerInjected { iter, learner, delay_ns } => evs.push(instant(
                "straggle",
                lane(*learner),
                at,
                format!("\"iter\":{iter},\"delay_ms\":{:.3}", *delay_ns as f64 / 1e6),
            )),
            Event::ResultArrival { iter, learner, disposition, compute_ns, .. } => {
                let args = format!(
                    "\"iter\":{iter},\"disposition\":\"{}\",\"compute_ms\":{:.3}",
                    disposition.name(),
                    *compute_ns as f64 / 1e6
                );
                match open_task.remove(&(*iter, *learner)) {
                    Some(t0) => evs.push(span("task", lane(*learner), t0, at, args)),
                    None => evs.push(instant("arrival", lane(*learner), at, args)),
                }
            }
            Event::ResultCancelled { iter, learner, compute_ns, .. } => {
                let args =
                    format!("\"iter\":{iter},\"compute_ms\":{:.3}", *compute_ns as f64 / 1e6);
                match open_task.remove(&(*iter, *learner)) {
                    Some(t0) => evs.push(span("cancelled", lane(*learner), t0, at, args)),
                    None => evs.push(instant("cancelled", lane(*learner), at, args)),
                }
            }
            Event::RankAdvance { rank, .. } => {
                evs.push(counter("rank", at, format!("\"rank\":{rank}")))
            }
            Event::DecodableAt { iter, front_ns } => evs.push(instant(
                "decodable",
                0,
                at,
                format!("\"iter\":{iter},\"front_ms\":{:.3}", *front_ns as f64 / 1e6),
            )),
            Event::DecodeDone { iter, method, cache_hit } => evs.push(instant(
                "decode",
                0,
                at,
                format!("\"iter\":{iter},\"method\":\"{}\",\"cache_hit\":{cache_hit}", esc(method)),
            )),
            Event::FrameRecv { learner, bytes } => {
                evs.push(instant("frame", lane(*learner), at, format!("\"bytes\":{bytes}")))
            }
            Event::PoolSample { hits, misses, resident } => evs.push(counter(
                "pool",
                at,
                format!("\"hits\":{hits},\"misses\":{misses},\"resident\":{resident}"),
            )),
            Event::NetSample { broadcast_ns, return_ns } => evs.push(counter(
                "net_ms",
                at,
                format!(
                    "\"broadcast\":{:.3},\"return\":{:.3}",
                    *broadcast_ns as f64 / 1e6,
                    *return_ns as f64 / 1e6
                ),
            )),
            Event::CrashInjected { iter, learner, down_ns } => {
                let down = match down_ns {
                    Some(ns) => format!("{:.3}", *ns as f64 / 1e6),
                    None => "\"permanent\"".into(),
                };
                evs.push(instant(
                    "crash",
                    lane(*learner),
                    at,
                    format!("\"iter\":{iter},\"down_ms\":{down}"),
                ));
            }
            Event::LearnerSuspected { iter, learner, misses } => evs.push(instant(
                "suspected",
                lane(*learner),
                at,
                format!("\"iter\":{iter},\"misses\":{misses}"),
            )),
            Event::LearnerDeclaredDead { iter, learner, misses } => evs.push(instant(
                "dead",
                lane(*learner),
                at,
                format!("\"iter\":{iter},\"misses\":{misses}"),
            )),
            Event::MembershipRemap { iter, survivors, dead } => evs.push(instant(
                "remap",
                0,
                at,
                format!("\"iter\":{iter},\"survivors\":{survivors},\"dead\":{dead}"),
            )),
            Event::DegradedDecode { iter, survivors, rank, fallback } => evs.push(instant(
                "degraded",
                0,
                at,
                format!(
                    "\"iter\":{iter},\"survivors\":{survivors},\"rank\":{rank},\"fallback\":{fallback}"
                ),
            )),
            Event::PlanSwitch { iter, epoch, scheme, rows } => evs.push(instant(
                "plan_switch",
                0,
                at,
                format!(
                    "\"iter\":{iter},\"epoch\":{epoch},\"scheme\":\"{}\",\"rows\":{rows}",
                    esc(scheme)
                ),
            )),
            Event::EstimateUpdate { iter, k_milli, delay_ns, waste_ns_per_iter } => evs
                .push(counter(
                    "estimate",
                    at,
                    format!(
                        "\"k\":{:.3},\"delay_ms\":{:.3},\"waste_ms\":{:.3}",
                        *k_milli as f64 / 1e3,
                        *delay_ns as f64 / 1e6,
                        *waste_ns_per_iter as f64 / 1e6
                    ),
                )),
            Event::CorruptionInjected { iter, learner, mode } => evs.push(instant(
                "corrupted",
                lane(*learner),
                at,
                format!("\"iter\":{iter},\"mode\":\"{}\"", esc(mode)),
            )),
            Event::VerifyFailed { iter, learner, identified } => {
                // Unidentified failures have no learner to pin: they
                // land on the controller lane (learner = u32::MAX).
                let tid = if *identified { lane(*learner) } else { 0 };
                evs.push(instant(
                    "verify_failed",
                    tid,
                    at,
                    format!("\"iter\":{iter},\"identified\":{identified}"),
                ));
            }
            Event::LearnerQuarantined { iter, learner } => evs.push(instant(
                "quarantined",
                lane(*learner),
                at,
                format!("\"iter\":{iter}"),
            )),
            Event::PipelineStall { iter, stall_ns } => evs.push(instant(
                "pipeline_stall",
                0,
                at,
                format!("\"iter\":{iter},\"stall_ms\":{:.3}", *stall_ns as f64 / 1e6),
            )),
            Event::ShardMerge { iter, shard, rank } => evs.push(instant(
                "shard_merge",
                0,
                at,
                format!("\"iter\":{iter},\"shard\":{shard},\"rank\":{rank}"),
            )),
            Event::IngressQueued { iter, learner, queued_ns } => evs.push(instant(
                "ingress_queued",
                lane(*learner),
                at,
                format!("\"iter\":{iter},\"queued_ms\":{:.3}", *queued_ns as f64 / 1e6),
            )),
        }
    }

    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    for (i, e) in evs.iter().enumerate() {
        out.push_str(e);
        if i + 1 < evs.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]}\n");
    out
}

pub fn write_chrome_trace(
    events: &[TracedEvent],
    n_learners: usize,
    path: &Path,
) -> std::io::Result<()> {
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(chrome_trace(events, n_learners).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::Disposition;
    use crate::runtime::json::Json;

    fn sample_events() -> Vec<TracedEvent> {
        let ms = Duration::from_millis;
        vec![
            TracedEvent { at: ms(0), event: Event::IterStart { iter: 1 } },
            TracedEvent { at: ms(0), event: Event::BroadcastBody { iter: 1, bytes: 2048 } },
            TracedEvent { at: ms(1), event: Event::TaskSent { iter: 1, learner: 0, bytes: 41 } },
            TracedEvent { at: ms(1), event: Event::TaskSent { iter: 1, learner: 1, bytes: 41 } },
            TracedEvent {
                at: ms(1),
                event: Event::StragglerInjected { iter: 1, learner: 1, delay_ns: 5_000_000 },
            },
            TracedEvent {
                at: ms(3),
                event: Event::ResultArrival {
                    iter: 1,
                    learner: 0,
                    disposition: Disposition::Used,
                    bytes: 100,
                    compute_ns: 2_000_000,
                },
            },
            TracedEvent { at: ms(3), event: Event::RankAdvance { iter: 1, rank: 1 } },
            TracedEvent { at: ms(8), event: Event::DecodableAt { iter: 1, front_ns: 5_000_000 } },
            TracedEvent {
                at: ms(8),
                event: Event::DecodeDone { iter: 1, method: "qr", cache_hit: false },
            },
            TracedEvent {
                at: ms(9),
                event: Event::ResultCancelled { iter: 1, learner: 1, bytes: 100, compute_ns: 7 },
            },
            TracedEvent { at: ms(9), event: Event::IterEnd { iter: 1 } },
        ]
    }

    fn str_of<'a>(e: &'a Json, k: &str) -> Option<&'a str> {
        e.get(k).ok().and_then(|v| v.as_str().ok())
    }

    fn num_of(e: &Json, k: &str) -> Option<f64> {
        e.get(k).ok().and_then(|v| v.as_f64().ok())
    }

    /// The Chrome trace must parse with the repo's own JSON parser and
    /// contain the expected lanes and spans (what Perfetto renders).
    #[test]
    fn chrome_trace_parses_and_has_lanes() {
        let txt = chrome_trace(&sample_events(), 2);
        let doc = Json::parse(&txt).expect("trace must be valid JSON");
        let evs = doc.get("traceEvents").unwrap().as_arr().expect("traceEvents array");
        // lanes: controller + 2 learners named via metadata
        let names: Vec<&str> = evs
            .iter()
            .filter(|e| str_of(e, "ph") == Some("M"))
            .filter_map(|e| e.get("args").ok().and_then(|a| str_of(a, "name")))
            .collect();
        assert!(names.contains(&"controller"), "{names:?}");
        assert!(names.contains(&"learner 0") && names.contains(&"learner 1"), "{names:?}");
        // exactly one iteration span, with a duration
        let iters: Vec<_> = evs
            .iter()
            .filter(|e| str_of(e, "ph") == Some("X") && str_of(e, "name") == Some("iter"))
            .collect();
        assert_eq!(iters.len(), 1);
        assert!(num_of(iters[0], "dur").unwrap() > 0.0);
        // learner 0's task became a span on its lane; learner 1's a
        // cancelled span
        let task =
            evs.iter().find(|e| str_of(e, "name") == Some("task")).expect("task span");
        assert_eq!(num_of(task, "tid"), Some(1.0));
        assert!(evs.iter().any(|e| str_of(e, "name") == Some("cancelled")));
        // rank counter present
        assert!(evs.iter().any(|e| str_of(e, "ph") == Some("C")));
    }

    /// Every JSONL line must parse independently and carry the event
    /// tag plus a timestamp.
    #[test]
    fn jsonl_lines_parse_independently() {
        let txt = jsonl(&sample_events());
        let lines: Vec<&str> = txt.lines().collect();
        assert_eq!(lines.len(), 11);
        for l in &lines {
            let v = Json::parse(l).unwrap_or_else(|e| panic!("bad line {l}: {e}"));
            assert!(num_of(&v, "t_ns").is_some(), "{l}");
            assert!(str_of(&v, "ev").is_some(), "{l}");
        }
        assert!(txt.contains("\"disposition\":\"used\""));
        assert!(txt.contains("\"ev\":\"result_cancelled\""));
    }

    #[test]
    fn escapes_control_characters() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    /// The fault-lifecycle events flow through both exporters: valid
    /// JSON lines with their tags, and Chrome instants on the right
    /// lanes (crash/suspect/dead on the learner's lane, remap/degraded
    /// on the controller's).
    #[test]
    fn fault_events_flow_through_both_exporters() {
        let ms = Duration::from_millis;
        let events = vec![
            TracedEvent {
                at: ms(1),
                event: Event::CrashInjected { iter: 3, learner: 1, down_ns: None },
            },
            TracedEvent {
                at: ms(2),
                event: Event::CrashInjected { iter: 3, learner: 0, down_ns: Some(5_000_000) },
            },
            TracedEvent {
                at: ms(4),
                event: Event::LearnerSuspected { iter: 4, learner: 1, misses: 2 },
            },
            TracedEvent {
                at: ms(6),
                event: Event::LearnerDeclaredDead { iter: 5, learner: 1, misses: 3 },
            },
            TracedEvent {
                at: ms(6),
                event: Event::MembershipRemap { iter: 5, survivors: 3, dead: 1 },
            },
            TracedEvent {
                at: ms(8),
                event: Event::DegradedDecode { iter: 7, survivors: 2, rank: 1, fallback: true },
            },
        ];
        let txt = jsonl(&events);
        for l in txt.lines() {
            Json::parse(l).unwrap_or_else(|e| panic!("bad line {l}: {e}"));
        }
        for tag in [
            "crash_injected",
            "learner_suspected",
            "learner_declared_dead",
            "membership_remap",
            "degraded_decode",
        ] {
            assert!(txt.contains(&format!("\"ev\":\"{tag}\"")), "missing {tag} in {txt}");
        }
        assert!(txt.contains("\"down_ns\":null"), "permanent crash encodes null downtime");
        assert!(txt.contains("\"down_ns\":5000000"));

        let trace = chrome_trace(&events, 2);
        let doc = Json::parse(&trace).expect("trace must be valid JSON");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let find = |name: &str| {
            evs.iter()
                .find(|e| str_of(e, "name") == Some(name))
                .unwrap_or_else(|| panic!("no {name} instant"))
        };
        assert_eq!(num_of(find("crash"), "tid"), Some(2.0), "learner 1 lane");
        assert_eq!(num_of(find("suspected"), "tid"), Some(2.0));
        assert_eq!(num_of(find("dead"), "tid"), Some(2.0));
        assert_eq!(num_of(find("remap"), "tid"), Some(0.0), "controller lane");
        assert_eq!(num_of(find("degraded"), "tid"), Some(0.0));
    }

    /// The byzantine-lifecycle events flow through both exporters:
    /// valid JSON lines with their tags, and Chrome instants on the
    /// right lanes (corruption/quarantine on the learner's lane, an
    /// unidentified verify failure on the controller's).
    #[test]
    fn byzantine_events_flow_through_both_exporters() {
        let ms = Duration::from_millis;
        let events = vec![
            TracedEvent {
                at: ms(1),
                event: Event::CorruptionInjected { iter: 2, learner: 1, mode: "bitflip" },
            },
            TracedEvent {
                at: ms(2),
                event: Event::VerifyFailed { iter: 2, learner: 1, identified: true },
            },
            TracedEvent {
                at: ms(3),
                event: Event::VerifyFailed { iter: 3, learner: u32::MAX, identified: false },
            },
            TracedEvent { at: ms(4), event: Event::LearnerQuarantined { iter: 4, learner: 1 } },
        ];
        let txt = jsonl(&events);
        for l in txt.lines() {
            Json::parse(l).unwrap_or_else(|e| panic!("bad line {l}: {e}"));
        }
        for tag in ["corruption_injected", "verify_failed", "learner_quarantined"] {
            assert!(txt.contains(&format!("\"ev\":\"{tag}\"")), "missing {tag} in {txt}");
        }
        assert!(txt.contains("\"mode\":\"bitflip\""), "{txt}");
        assert!(txt.contains("\"identified\":true") && txt.contains("\"identified\":false"));

        let trace = chrome_trace(&events, 2);
        let doc = Json::parse(&trace).expect("trace must be valid JSON");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let find = |name: &str| {
            evs.iter()
                .find(|e| str_of(e, "name") == Some(name))
                .unwrap_or_else(|| panic!("no {name} instant"))
        };
        assert_eq!(num_of(find("corrupted"), "tid"), Some(2.0), "learner 1 lane");
        assert_eq!(num_of(find("quarantined"), "tid"), Some(2.0));
        let verify_tids: Vec<f64> = evs
            .iter()
            .filter(|e| str_of(e, "name") == Some("verify_failed"))
            .filter_map(|e| num_of(e, "tid"))
            .collect();
        assert!(
            verify_tids.contains(&2.0) && verify_tids.contains(&0.0),
            "identified → learner lane, unidentified → controller: {verify_tids:?}"
        );
    }

    /// The pipeline/shard/incast events flow through both exporters:
    /// valid JSON lines with their tags, and Chrome instants on the
    /// right lanes (stall/merge on the controller's, queueing on the
    /// learner's).
    #[test]
    fn pipeline_events_flow_through_both_exporters() {
        let ms = Duration::from_millis;
        let events = vec![
            TracedEvent {
                at: ms(1),
                event: Event::PipelineStall { iter: 4, stall_ns: 2_000_000 },
            },
            TracedEvent { at: ms(2), event: Event::ShardMerge { iter: 4, shard: 1, rank: 3 } },
            TracedEvent {
                at: ms(3),
                event: Event::IngressQueued { iter: 4, learner: 1, queued_ns: 750_000 },
            },
        ];
        let txt = jsonl(&events);
        for l in txt.lines() {
            Json::parse(l).unwrap_or_else(|e| panic!("bad line {l}: {e}"));
        }
        for tag in ["pipeline_stall", "shard_merge", "ingress_queued"] {
            assert!(txt.contains(&format!("\"ev\":\"{tag}\"")), "missing {tag} in {txt}");
        }
        assert!(txt.contains("\"stall_ns\":2000000"), "{txt}");
        assert!(txt.contains("\"shard\":1") && txt.contains("\"rank\":3"), "{txt}");
        assert!(txt.contains("\"queued_ns\":750000"), "{txt}");

        let trace = chrome_trace(&events, 2);
        let doc = Json::parse(&trace).expect("trace must be valid JSON");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let find = |name: &str| {
            evs.iter()
                .find(|e| str_of(e, "name") == Some(name))
                .unwrap_or_else(|| panic!("no {name} instant"))
        };
        assert_eq!(num_of(find("pipeline_stall"), "tid"), Some(0.0), "controller lane");
        assert_eq!(num_of(find("shard_merge"), "tid"), Some(0.0));
        assert_eq!(num_of(find("ingress_queued"), "tid"), Some(2.0), "learner 1 lane");
    }

    /// The adaptive-plan events flow through both exporters: a
    /// plan_switch instant on the controller lane and an estimate
    /// counter track.
    #[test]
    fn plan_events_flow_through_both_exporters() {
        let ms = Duration::from_millis;
        let events = vec![
            TracedEvent {
                at: ms(2),
                event: Event::EstimateUpdate {
                    iter: 6,
                    k_milli: 2500,
                    delay_ns: 80_000_000,
                    waste_ns_per_iter: 1_000_000,
                },
            },
            TracedEvent {
                at: ms(3),
                event: Event::PlanSwitch { iter: 6, epoch: 1, scheme: "mds", rows: 15 },
            },
        ];
        let txt = jsonl(&events);
        for l in txt.lines() {
            Json::parse(l).unwrap_or_else(|e| panic!("bad line {l}: {e}"));
        }
        assert!(txt.contains("\"ev\":\"estimate_update\""), "{txt}");
        assert!(txt.contains("\"k_milli\":2500"), "{txt}");
        assert!(txt.contains("\"ev\":\"plan_switch\""), "{txt}");
        assert!(txt.contains("\"epoch\":1") && txt.contains("\"scheme\":\"mds\""), "{txt}");

        let trace = chrome_trace(&events, 1);
        let doc = Json::parse(&trace).expect("trace must be valid JSON");
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let switch = evs
            .iter()
            .find(|e| str_of(e, "name") == Some("plan_switch"))
            .expect("plan_switch instant");
        assert_eq!(num_of(switch, "tid"), Some(0.0), "controller lane");
        assert!(
            evs.iter()
                .any(|e| str_of(e, "ph") == Some("C") && str_of(e, "name") == Some("estimate")),
            "estimate counter track"
        );
    }
}
