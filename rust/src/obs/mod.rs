//! Observability: structured event tracing, streaming quantiles, and
//! straggler attribution for the coded training loop.
//!
//! The paper's whole argument is about *which* learners straggle and
//! *when* the received prefix becomes decodable — quantities the
//! per-phase means in [`crate::metrics`] cannot show. This module adds
//! the missing telemetry substrate, hand-rolled like the rest of the
//! repo (no serde / tracing / log crates):
//!
//! * [`Event`] / [`Tracer`] / [`EventLog`] — a bounded ring buffer of
//!   timestamped hot-loop events (task sends, arrivals with their
//!   disposition, rank advances, decode outcomes, cancellations),
//!   stamped off a [`crate::sim::ClockRef`] so real and virtual-time
//!   runs share one code path. **Off by default**: a disabled tracer's
//!   `record` is a branch on a plain bool and never constructs the
//!   event, so the traced loop is bit-identical to the untraced one
//!   (pinned by `tests/obs_integration.rs`).
//! * [`export`] — JSONL and Chrome trace-event writers
//!   (`--trace-out run.trace.json`; load in Perfetto / `chrome://tracing`,
//!   one lane per learner plus one for the controller).
//! * [`quantile`] — a streaming P² sketch ([`Quantiles`]: p50/p90/p99
//!   without storing samples), replacing mean-only reporting in sweep
//!   tables and `BENCH_*.json`.
//! * [`attribution`] — per-learner straggler attribution (arrival-rank
//!   histograms, tail-latency quantiles, injected-vs-organic split),
//!   decodability-front stats, and wasted-work accounting
//!   ([`WasteStats`]: bytes + compute of post-decodable / cancelled
//!   results).
//! * [`log`] — the tiny leveled stderr logger (`CODED_MARL_LOG=
//!   error|warn|info|debug|off`) that replaced the ad-hoc `eprintln!`
//!   calls in `coordinator/` and `sim/`.
//!
//! ROADMAP item 1 (Adaptive Gradient Coding's online disturbance
//! estimator) consumes this layer: [`Attribution`] and the event
//! stream are exactly the observed-straggler signal it needs.

pub mod attribution;
pub mod event;
pub mod export;
pub mod log;
pub mod quantile;
pub mod trace;

pub use attribution::{AttrSummary, Attribution, WasteStats};
pub use event::{Disposition, Event, TracedEvent};
pub use log::Level;
pub use quantile::{P2Quantile, Quantiles};
pub use trace::{EventLog, Tracer, DEFAULT_EVENT_CAP};
