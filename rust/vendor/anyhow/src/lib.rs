//! Minimal offline shim of the `anyhow` 1.x API surface that
//! `coded-marl` uses: [`Error`], [`Result`], the [`anyhow!`] and
//! [`bail!`] macros, and the [`Context`] extension trait.
//!
//! Semantics mirror the real crate where it matters here:
//!
//! * `{e}` (Display) prints the outermost message only;
//! * `{e:#}` (alternate Display) prints the whole context chain joined
//!   with `": "`;
//! * `{e:?}` (Debug) prints the outermost message followed by a
//!   `Caused by:` list;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its source chain.
//!
//! Like the real `anyhow::Error`, [`Error`] deliberately does **not**
//! implement `std::error::Error` — that is what makes the blanket
//! `From` impl coherent next to `impl From<T> for T`.

use std::fmt;

/// An error chain: `chain[0]` is the outermost (most recent) context,
/// later entries are the causes.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `Context` adds).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] as the
/// default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("loading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: file missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "42".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);
        fn bad() -> Result<i32> {
            let n: i32 = "x".parse()?;
            Ok(n)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(x: i32) -> Result<()> {
            if x > 3 {
                bail!("too big: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        let e = f(5).unwrap_err();
        assert_eq!(e.to_string(), "too big: 5");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn context_on_anyhow_result_stacks() {
        let e: Error = Err::<(), _>(Error::msg("inner"))
            .context("mid")
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: mid: inner");
        assert_eq!(e.root_cause(), "inner");
    }
}
