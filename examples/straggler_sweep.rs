//! Straggler sweep: reproduce the *shape* of the paper's Figs. 4-5 on
//! one environment interactively — mean training time per iteration for
//! every coding scheme as the straggler count k and delay t_s vary.
//!
//!     cargo run --release --example straggler_sweep
//!     CODED_MARL_SWEEP_BACKEND=pjrt cargo run --release --example straggler_sweep
//!
//! Defaults to the mock backend (compute time calibrated to the paper's
//! regime) so the sweep finishes in seconds; set the env var above to
//! run the real PJRT learner step instead. One learner pool is reused
//! across all (scheme, k) cells — the assignment row travels with each
//! task, so reconfiguring the code is free.

use std::time::Duration;

use coded_marl::coding::Scheme;
use coded_marl::config::{Backend, StragglerConfig, TrainConfig};
use coded_marl::coordinator::{backend_factory, spawn_local, Controller, RunSpec};
use coded_marl::env::EnvKind;
use coded_marl::metrics::table::Table;

fn main() -> anyhow::Result<()> {
    let backend = match std::env::var("CODED_MARL_SWEEP_BACKEND").as_deref() {
        Ok("pjrt") => Backend::Pjrt,
        _ => Backend::Mock,
    };
    let artifacts = std::env::var("CODED_MARL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // Paper §V-C, cooperative navigation: M = 8, N = 15, k ∈ {0, 1, 2},
    // t_s = 0.25 s. Delays are scaled 1/10 (25 ms) so the sweep is
    // interactive; the bench binaries report the scale factor too.
    let m = 8;
    let n = 15;
    let ks = [0usize, 1, 2, 4, 7];
    let t_s = Duration::from_millis(25);

    let mut cfg = TrainConfig::new("coop_nav_m8");
    cfg.n_learners = n;
    cfg.backend = backend;
    cfg.iterations = 10;
    cfg.episodes_per_iter = 1;
    cfg.episode_len = 25;
    cfg.warmup_iters = 1;
    cfg.mock_compute = Duration::from_millis(2);
    cfg.seed = 3;

    let spec = RunSpec::synthetic(EnvKind::CoopNav, m, 0, 64, 32);
    println!(
        "straggler sweep: coop_nav M={m} N={n} t_s={t_s:?} backend={} ({} iters/cell)",
        cfg.backend.name(),
        cfg.iterations
    );

    let mut table = Table::new(&[
        "scheme", "k=0", "k=1", "k=2", "k=4", "k=7", "redundancy", "tolerance",
    ]);
    for scheme in Scheme::ALL {
        let mut cells = vec![scheme.name().to_string()];
        let mut code_info: Option<(f64, usize)> = None;
        for &k in &ks {
            let mut c = cfg.clone();
            c.scheme = scheme;
            c.straggler = StragglerConfig::fixed(k, t_s);
            let factory = backend_factory(&c, &artifacts, &spec);
            let pool = spawn_local(c.n_learners, factory)?;
            let mut ctrl = Controller::new(c, spec.clone(), pool)?;
            ctrl.train()?;
            if code_info.is_none() {
                code_info = Some((ctrl.code().redundancy(), ctrl.code().worst_case_tolerance()));
            }
            // skip warmup iterations when averaging (no learner round)
            let times: Vec<f64> = ctrl
                .log
                .records
                .iter()
                .filter(|r| r.decode_method != "warmup")
                .map(|r| r.timing.total.as_secs_f64() * 1e3)
                .collect();
            let mean = times.iter().sum::<f64>() / times.len() as f64;
            cells.push(format!("{mean:.1}ms"));
            ctrl.shutdown();
        }
        let (red, tol) = code_info.unwrap();
        cells.push(format!("{red:.1}x"));
        cells.push(tol.to_string());
        table.row(&cells);
    }
    print!("{}", table.render());
    println!(
        "\nExpected shape (paper Figs. 4-5): uncoded fastest at k=0 but +t_s for any k>0;\n\
         MDS/random-sparse flat until k > N-M = {}; replication/LDPC cheap but fragile at high k.",
        n - m
    );
    Ok(())
}
