//! Straggler sweep: reproduce the *shape* of the paper's Figs. 4-5 on
//! one environment — mean training time per iteration for every coding
//! scheme as the straggler count k varies.
//!
//!     cargo run --release --example straggler_sweep
//!     cargo run --release --example straggler_sweep -- --time-mode real
//!     cargo run --release --example straggler_sweep -- --time-mode real --ts-ms 25
//!
//! Default is **virtual time** (`sim::SimTransport` + `VirtualClock`):
//! the paper's full t_s = 250 ms is injected per straggler, but delays
//! and emulated compute advance a virtual clock instead of sleeping,
//! so the whole grid prints in well under a second while reporting the
//! same per-iteration means a real-time run measures (within noise).
//! `--time-mode real` runs the identical protocol on learner threads
//! with real sleeps — expect the uncoded column alone to cost
//! ~t_s × iterations of wall-clock per k > 0 cell.

use std::time::Duration;

use coded_marl::cli::Args;
use coded_marl::config::{Backend, TimeMode};
use coded_marl::coordinator::RunSpec;
use coded_marl::env::EnvKind;
use coded_marl::metrics::table::fmt_duration;
use coded_marl::sim::sweep::{render_table, run_sweep, simulated_total, sweep_base, SweepConfig};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1)?;
    // CODED_MARL_SWEEP_BACKEND=pjrt runs the real XLA learner step
    // (needs artifacts; CODED_MARL_ARTIFACTS points elsewhere than
    // ./artifacts). PJRT compute is real work, so it implies real time
    // unless --time-mode says otherwise.
    let backend = match std::env::var("CODED_MARL_SWEEP_BACKEND").as_deref() {
        Ok("pjrt") => Backend::Pjrt,
        _ => Backend::Mock,
    };
    let time_mode = match args.opt("time-mode") {
        None if backend == Backend::Pjrt => TimeMode::Real,
        None => TimeMode::Virtual,
        Some(v) => TimeMode::parse(v)
            .ok_or_else(|| anyhow::anyhow!("unknown --time-mode '{v}' (real|virtual)"))?,
    };
    // Paper §V-C, cooperative navigation: M = 8, N = 15, t_s = 0.25 s.
    // Virtual time makes the full delay free; in real mode pass
    // `--ts-ms 25` for the old interactive 1/10 scale.
    let t_s = Duration::from_millis(args.get_or("ts-ms", 250u64)?);
    let iterations = args.get_or("iterations", 10usize)?;
    // Virtual-time cells shard across threads (0 = all cores); real
    // mode ignores this and runs serially.
    let sweep_threads = args.get_or("sweep-threads", 0usize)?;
    args.finish()?;

    let m = 8;
    let n = 15;
    let ks = vec![0usize, 1, 2, 4, 7];

    // Calibrated to the paper's regime: with an 8-agent MDS workload
    // 10 ms/update puts compute at ~80 ms/iteration, so overhead noise
    // in the real-mode reference stays ≪ 1% of the mean.
    let mut cfg = sweep_base("coop_nav_m8", n, iterations, Duration::from_millis(10), 3);
    cfg.time_mode = time_mode;
    cfg.backend = backend;
    cfg.sweep_threads = sweep_threads;

    // Small synthetic model dims: the mock's *reported* time is the
    // modeled mock_compute, not its actual arithmetic, so lean dims
    // only cut the sweep's wall cost (they change no timing result).
    let spec = RunSpec::synthetic(EnvKind::CoopNav, m, 0, 32, 32);
    println!(
        "straggler sweep: coop_nav M={m} N={n} t_s={t_s:?} time={} ({iterations} iters/cell)",
        time_mode.name(),
    );

    let t0 = std::time::Instant::now();
    let cells = run_sweep(&SweepConfig {
        base: cfg,
        spec,
        schemes: coded_marl::coding::Scheme::ALL.to_vec(),
        ks: ks.clone(),
        delay: t_s,
        artifacts_dir: std::env::var("CODED_MARL_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".into())
            .into(),
    })?;
    let wall = t0.elapsed();
    print!("{}", render_table(&cells, &ks));
    let simulated = simulated_total(&cells);
    println!(
        "\n{} of training time in {} wall-clock ({})",
        fmt_duration(simulated),
        fmt_duration(wall),
        time_mode.name(),
    );
    println!(
        "Expected shape (paper Figs. 4-5): uncoded fastest at k=0 but +t_s for any k>0;\n\
         MDS/random-sparse flat until k > N-M = {}; replication/LDPC cheap but fragile at high k.",
        n - m
    );
    Ok(())
}
