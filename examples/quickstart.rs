//! Quickstart: train coded distributed MADDPG on the tiny cooperative
//! navigation preset and print the run summary.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Uses the real PJRT backend (each learner thread compiles the AOT
//! artifacts at startup) with an MDS code over N = 5 learners for M = 3
//! agents, and injects one straggler per iteration — the coded run
//! masks it completely.

use coded_marl::coding::Scheme;
use coded_marl::config::{StragglerConfig, TrainConfig};
use coded_marl::coordinator::run_training;
use coded_marl::metrics::table::fmt_duration;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("CODED_MARL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    let mut cfg = TrainConfig::new("quickstart_m3");
    cfg.n_learners = 5;
    cfg.scheme = Scheme::Mds;
    // one straggler with a 100 ms delay every iteration — MDS tolerates
    // N − M = 2, so training speed is unaffected
    cfg.straggler = StragglerConfig::fixed(1, std::time::Duration::from_millis(100));
    cfg.iterations = 25;
    cfg.episodes_per_iter = 2;
    cfg.episode_len = 25;
    cfg.warmup_iters = 2;
    cfg.seed = 7;
    cfg.verbose = true;

    eprintln!("quickstart: {}", cfg.summary());
    eprintln!("(compiling artifacts in 5 learner threads — first iteration includes XLA compile)");
    let t0 = std::time::Instant::now();
    let log = run_training(&cfg, &artifacts)?;

    println!("\n=== quickstart summary ===");
    println!("wall time:        {}", fmt_duration(t0.elapsed()));
    println!("mean iter time:   {}", fmt_duration(log.mean_iter_time()));
    let rewards = log.smoothed_rewards(5);
    println!(
        "reward (5-iter smoothed): first {:.2} -> last {:.2}",
        rewards.first().unwrap(),
        rewards.last().unwrap()
    );
    println!(
        "decode path used: {}",
        log.records.last().map(|r| r.decode_method).unwrap_or("-")
    );
    println!("\nNext steps:");
    println!("  cargo run --release -- train --preset coop_nav_m8 --scheme ldpc --verbose");
    println!("  cargo run --release -- code --scheme mds --n 15 --m 8");
    println!("  cargo run --release --example straggler_sweep");
    Ok(())
}
