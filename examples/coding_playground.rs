//! Coding playground: explore the four coding schemes of paper §III-C
//! without running any training — assignment matrices, workload
//! distribution, redundancy, worst-case straggler tolerance, random
//! erasure decodability, and decode-path timing.
//!
//!     cargo run --release --example coding_playground
//!     cargo run --release --example coding_playground -- --n 12 --m 6

use std::time::Instant;

use coded_marl::cli::Args;
use coded_marl::coding::decoder::{DecodeMethod, Decoder};
use coded_marl::coding::{random_set_decode_probability, Code, CodeParams, Scheme};
use coded_marl::metrics::table::Table;
use coded_marl::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1)?;
    let n = args.get_or("n", 15usize)?;
    let m = args.get_or("m", 8usize)?;
    let p = args.get_or("p", 10_000usize)?; // parameter vector length
    args.finish()?;

    println!("=== code anatomy: N={n} learners, M={m} agents ===\n");
    let mut summary = Table::new(&[
        "scheme", "redundancy", "min/max workload", "worst-case tol", "P(dec) k=N-M", "P(dec) k=N-M+2",
    ]);
    let mut rng = Pcg32::seeded(0);
    for scheme in Scheme::ALL {
        let code = Code::build(&CodeParams { scheme, n, m, p_m: 0.8, seed: 1 });
        let workloads: Vec<usize> = (0..n).map(|j| code.workload(j)).collect();
        let k_edge = n - m;
        summary.row(&[
            scheme.name().to_string(),
            format!("{:.2}x", code.redundancy()),
            format!(
                "{}/{}",
                workloads.iter().min().unwrap(),
                workloads.iter().max().unwrap()
            ),
            code.worst_case_tolerance().to_string(),
            format!("{:.2}", random_set_decode_probability(&code, k_edge, 300, &mut rng)),
            format!(
                "{:.2}",
                random_set_decode_probability(&code, (k_edge + 2).min(n), 300, &mut rng)
            ),
        ]);
    }
    print!("{}", summary.render());

    println!("\n=== replication vs LDPC assignment structure (binary codes) ===");
    for scheme in [Scheme::Replication, Scheme::Ldpc] {
        let code = Code::build(&CodeParams { scheme, n, m, p_m: 0.8, seed: 1 });
        println!("\n{scheme}:");
        for j in 0..n {
            let row: String = code
                .c
                .row(j)
                .iter()
                .map(|&v| if v != 0.0 { '#' } else { '.' })
                .collect();
            println!("  L{j:<3} {row}");
        }
    }

    println!("\n=== decode timing (P = {p} parameters/agent) ===");
    let mut timing = Table::new(&["scheme", "erasures", "method", "decode time", "max err"]);
    let mut rng = Pcg32::seeded(7);
    for scheme in Scheme::ALL {
        let code = Code::build(&CodeParams { scheme, n, m, p_m: 0.8, seed: 1 });
        let decoder = Decoder::new(code.clone());
        let theta: Vec<Vec<f32>> = (0..m).map(|_| rng.normal_vec_f32(p, 1.0)).collect();
        // drop as many learners as the scheme can surely tolerate
        let drop = code.worst_case_tolerance();
        let received: Vec<usize> = (drop..n).collect();
        let results: Vec<Vec<f32>> = received
            .iter()
            .map(|&j| {
                let mut y = vec![0.0f32; p];
                for &(i, c) in code.assignments(j) {
                    for (acc, &t) in y.iter_mut().zip(&theta[i]) {
                        *acc += c as f32 * t;
                    }
                }
                y
            })
            .collect();
        for method in [DecodeMethod::Auto, DecodeMethod::Qr] {
            let t0 = Instant::now();
            let out = decoder.decode(&received, &results, method)?;
            let dt = t0.elapsed();
            let mut err = 0.0f32;
            for i in 0..m {
                for k in 0..p {
                    err = err.max((out.theta[i][k] - theta[i][k]).abs());
                }
            }
            timing.row(&[
                scheme.name().to_string(),
                drop.to_string(),
                out.method.to_string(),
                coded_marl::metrics::table::fmt_duration(dt),
                format!("{err:.1e}"),
            ]);
        }
    }
    print!("{}", timing.render());
    println!(
        "\nNote the peeling path (binary codes) vs QR: the paper's §III-C4 O(M) vs O(M³) claim\n\
         shows up as the decode-time gap; `cargo bench --bench decode_micro` sweeps this."
    );
    Ok(())
}
