//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real workload: trains MADDPG on
//! cooperative navigation through the **full stack** — Rust controller
//! and learner threads (L3), the AOT-lowered JAX learner step (L2), and
//! the Pallas fused-linear kernels inside it (L1) — for several hundred
//! iterations, with stragglers injected and masked by an MDS code, and
//! writes the reward/timing curves to runs/e2e/.
//!
//! It then replays the identical schedule centralized (single process,
//! same seeds) and reports the final-parameter divergence: the coded
//! run must match the centralized run up to decode round-off — the
//! paper's accuracy claim (Fig. 3).
//!
//!     cargo run --release --example e2e_train            # full run
//!     CODED_MARL_E2E_ITERS=50 cargo run ... (short run)

use coded_marl::config::{StragglerConfig, TrainConfig};
use coded_marl::coordinator::{
    backend_factory, Centralized, Controller, PjrtBackend, RunSpec,
};
use coded_marl::coding::Scheme;
use coded_marl::coordinator::spawn_local;
use coded_marl::metrics::table::fmt_duration;
use coded_marl::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("CODED_MARL_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let iters: usize = std::env::var("CODED_MARL_E2E_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);

    let mut cfg = TrainConfig::new("quickstart_m3");
    cfg.n_learners = 5;
    cfg.scheme = Scheme::Mds;
    cfg.straggler = StragglerConfig::fixed(1, std::time::Duration::from_millis(20));
    cfg.iterations = iters;
    cfg.episodes_per_iter = 4;
    cfg.episode_len = 25;
    cfg.warmup_iters = 5;
    cfg.noise_decay_iters = iters / 2;
    cfg.seed = 42;
    cfg.out_dir = Some("runs/e2e".into());

    println!("=== e2e: coded distributed MADDPG (L3 rust / L2 jax / L1 pallas) ===");
    println!("{}", cfg.summary());

    let manifest = Manifest::load(&artifacts)?;
    let spec = RunSpec::from_preset(manifest.preset(&cfg.preset)?)?;

    // ---- coded distributed run -------------------------------------
    let t0 = std::time::Instant::now();
    let factory = backend_factory(&cfg, &artifacts, &spec);
    let pool = spawn_local(cfg.n_learners, factory)?;
    let mut controller = Controller::new(cfg.clone(), spec.clone(), pool)?;
    controller.train()?;
    let coded_wall = t0.elapsed();
    let coded_agents: Vec<_> = controller.agents().to_vec();
    let log = std::mem::take(&mut controller.log);
    controller.shutdown();

    let smoothed = log.smoothed_rewards(25);
    println!("\n--- coded run ---");
    println!("wall time:      {}", fmt_duration(coded_wall));
    println!("mean iter time: {}", fmt_duration(log.mean_iter_time()));
    println!("reward curve (25-iter smoothed):");
    let stride = (iters / 12).max(1);
    for (i, r) in smoothed.iter().enumerate() {
        if i % stride == 0 || i + 1 == smoothed.len() {
            println!("  iter {i:>4}  reward {r:>10.3}");
        }
    }
    let first = smoothed.iter().take(20).sum::<f64>() / 20.0f64.min(smoothed.len() as f64);
    let last = smoothed.iter().rev().take(20).sum::<f64>() / 20.0f64.min(smoothed.len() as f64);
    println!("head mean {first:.3}  ->  tail mean {last:.3}");
    if iters >= 200 {
        assert!(
            last > first,
            "training should improve reward over {iters} iterations ({first:.3} -> {last:.3})"
        );
        println!("reward improved: OK");
    }

    // ---- centralized replay (same seeds) ----------------------------
    println!("\n--- centralized replay (accuracy reference, Fig. 3) ---");
    let t0 = std::time::Instant::now();
    let backend = Box::new(PjrtBackend::load(&artifacts, &cfg.preset)?);
    let mut central = Centralized::new(cfg.clone(), spec.clone(), backend)?;
    central.train()?;
    println!("wall time:      {}", fmt_duration(t0.elapsed()));
    let central_log = std::mem::take(&mut central.log);
    let c_sm = central_log.smoothed_rewards(25);
    println!(
        "centralized reward: head {:.3} -> tail {:.3}",
        c_sm.iter().take(20).sum::<f64>() / 20.0,
        c_sm.iter().rev().take(20).sum::<f64>() / 20.0
    );

    // Parameter-level agreement. Trajectories share every RNG stream;
    // divergence comes only from decode round-off compounding through
    // the environment, so we compare a *short* horizon exactly and the
    // long run statistically.
    let mut max_diff = 0.0f32;
    for (a, b) in coded_agents.iter().zip(central.agents()) {
        max_diff = max_diff.max(a.max_abs_diff(b));
    }
    println!("\nfinal-parameter max |coded - centralized| = {max_diff:.3e}");
    println!("(exact-equivalence over short horizons is pinned by \
              rust/tests/coordinator_integration.rs)");

    println!("\nCSV logs: runs/e2e/");
    Ok(())
}
