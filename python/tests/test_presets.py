"""Pins the python<->rust dimension contract (see presets.py docstring).

If any of these change, rust/src/env/ and rust/src/marl/params.rs must
change in lockstep — the manifest is the carrier, these tests are the
tripwire.
"""

import json
import os

import pytest

from compile import presets

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_obs_dim_formulas_pinned():
    assert presets.obs_dim("coop_nav", 8) == 34
    assert presets.obs_dim("coop_nav", 10) == 42
    assert presets.obs_dim("coop_nav", 3) == 14
    assert presets.obs_dim("predator_prey", 8) == 36
    assert presets.obs_dim("predator_prey", 10) == 44
    assert presets.obs_dim("deception", 8) == 24
    assert presets.obs_dim("keep_away", 10) == 28


def test_unknown_env_raises():
    with pytest.raises(ValueError):
        presets.obs_dim("nope", 4)


def test_param_dims_consistent():
    for p in presets.default_presets():
        d, h, a = p.obs_dim, p.hidden, p.act_dim
        assert p.actor_param_dim == d * h + h + h * h + h + h * a + a
        c = p.m * (d + a)
        assert p.critic_in_dim == c
        assert p.critic_param_dim == c * h + h + h * h + h + h + 1
        assert p.agent_param_dim == 2 * (p.actor_param_dim + p.critic_param_dim)


def test_default_presets_cover_paper_experiments():
    names = {p.name for p in presets.default_presets()}
    for env in presets.ENVS:
        for m in (8, 10):
            assert f"{env}_m{m}" in names
    assert "quickstart_m3" in names


def test_competitive_envs_have_k4():
    for p in presets.default_presets():
        if p.env in ("predator_prey", "deception", "keep_away") and p.m >= 8:
            assert p.n_adversaries == 4  # paper SsV-B
        if p.env == "coop_nav":
            assert p.n_adversaries == 0


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_matches_presets():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["interchange"] == "hlo_text"
    by_name = {e["name"]: e for e in man["presets"]}
    for p in presets.default_presets():
        e = by_name[p.name]
        assert e["obs_dim"] == p.obs_dim
        assert e["actor_param_dim"] == p.actor_param_dim
        assert e["critic_param_dim"] == p.critic_param_dim
        assert e["m"] == p.m and e["batch"] == p.batch
        for rel in e["artifacts"].values():
            assert os.path.exists(os.path.join(ART, rel)), rel
