"""L2 correctness: MADDPG learner step and forwards, Pallas vs reference,
plus the algebraic identities the coded recovery relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, presets

P = presets.preset_by_name("quickstart_m3")


def make_params(p, seed=0):
    tp = model.init_mlp(jax.random.PRNGKey(seed),
                        model.mlp_shapes(p.obs_dim, p.hidden, p.act_dim))
    tq = model.init_mlp(jax.random.PRNGKey(seed + 1),
                        model.mlp_shapes(p.critic_in_dim, p.hidden, 1))
    tpa = jnp.stack([
        model.init_mlp(jax.random.PRNGKey(seed + 10 + j),
                       model.mlp_shapes(p.obs_dim, p.hidden, p.act_dim))
        for j in range(p.m)
    ])
    return tp, tq, tpa, tq * 0.5


def make_batch(p, seed=0):
    rng = np.random.default_rng(seed)
    B, M = p.batch, p.m
    return (
        jnp.asarray(rng.normal(size=(B, M, p.obs_dim)), jnp.float32),
        jnp.asarray(rng.uniform(-1, 1, size=(B, M, p.act_dim)), jnp.float32),
        jnp.asarray(rng.normal(size=(B,)), jnp.float32),
        jnp.asarray(rng.normal(size=(B, M, p.obs_dim)), jnp.float32),
        jnp.asarray((rng.random(B) < 0.1).astype(np.float32)),
    )


# ---------------------------------------------------------------------------
# Packing
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip():
    shapes = model.mlp_shapes(7, 5, 3)
    rng = np.random.default_rng(0)
    blocks = [jnp.asarray(rng.normal(size=s), jnp.float32) for s in shapes]
    flat = model.pack(blocks)
    back = model.unpack(flat, shapes)
    for a, b in zip(blocks, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_param_dims_match_presets():
    for p in presets.default_presets():
        assert model.init_mlp(
            jax.random.PRNGKey(0), model.mlp_shapes(p.obs_dim, p.hidden, p.act_dim)
        ).shape == (p.actor_param_dim,)
        assert model.init_mlp(
            jax.random.PRNGKey(0), model.mlp_shapes(p.critic_in_dim, p.hidden, 1)
        ).shape == (p.critic_param_dim,)


# ---------------------------------------------------------------------------
# Forwards
# ---------------------------------------------------------------------------


def test_actor_forward_matches_ref_and_bounded():
    tp, _, _, _ = make_params(P)
    obs = make_batch(P)[0][:, 0, :]
    a = model.actor_forward(P, tp, obs)
    ar = model.actor_forward_ref(P, tp, obs)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ar), rtol=1e-5, atol=1e-6)
    assert np.all(np.abs(np.asarray(a)) <= 1.0)


def test_critic_forward_matches_ref():
    _, tq, _, _ = make_params(P)
    obs, act, *_ = make_batch(P)
    q = model.critic_forward(P, tq, obs.reshape(P.batch, -1), act.reshape(P.batch, -1))
    qr = model.critic_forward_ref(P, tq, obs.reshape(P.batch, -1), act.reshape(P.batch, -1))
    assert q.shape == (P.batch,)
    np.testing.assert_allclose(np.asarray(q), np.asarray(qr), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Learner step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("agent_idx", [0, 1, P.m - 1])
def test_learner_step_matches_ref(agent_idx):
    params = make_params(P)
    batch = make_batch(P)
    step = jax.jit(model.make_learner_step(P))
    stepr = model.make_learner_step_ref(P)
    out = step(*params, *batch, jnp.int32(agent_idx))
    outr = stepr(*params, *batch, jnp.int32(agent_idx))
    for a, b in zip(out, outr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4, atol=3e-5)


def test_learner_step_is_deterministic():
    params, batch = make_params(P), make_batch(P)
    step = jax.jit(model.make_learner_step(P))
    o1 = step(*params, *batch, jnp.int32(0))
    o2 = step(*params, *batch, jnp.int32(0))
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_polyak_identity():
    """theta_hat' must be exactly tau*theta_hat + (1-tau)*theta' (Eq. 5)."""
    params, batch = make_params(P), make_batch(P)
    tp, tq, tpa, tqh = params
    out = model.make_learner_step_ref(P)(*params, *batch, jnp.int32(1))
    tp_new, tq_new, tph_new, tqh_new = out[:4]
    np.testing.assert_allclose(
        np.asarray(tph_new),
        P.tau * np.asarray(tpa[1]) + (1 - P.tau) * np.asarray(tp_new),
        rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(tqh_new),
        P.tau * np.asarray(tqh) + (1 - P.tau) * np.asarray(tq_new),
        rtol=1e-6, atol=1e-7)


def test_critic_update_is_gradient_descent_direction():
    """One SGD step must not increase the TD loss (small lr)."""
    params, batch = make_params(P), make_batch(P)
    tp, tq, tpa, tqh = params
    obs, act, rew, obs2, done = batch
    out = model.make_learner_step_ref(P)(*params, *batch, jnp.int32(0))
    tq_new = out[1]

    def td_loss(tq_):
        a2 = [model.actor_forward_ref(P, tpa[j], obs2[:, j, :]) for j in range(P.m)]
        qn = model.critic_forward_ref(P, tqh, obs2.reshape(P.batch, -1),
                                      jnp.concatenate(a2, axis=1))
        tgt = rew + P.gamma * (1 - done) * qn
        q = model.critic_forward_ref(P, tq_, obs.reshape(P.batch, -1),
                                     act.reshape(P.batch, -1))
        return float(jnp.mean((tgt - q) ** 2))

    assert td_loss(tq_new) <= td_loss(tq) + 1e-6


def test_policy_update_increases_objective():
    params, batch = make_params(P), make_batch(P)
    tp, tq, tpa, tqh = params
    obs, act, rew, obs2, done = batch
    i = 2
    out = model.make_learner_step_ref(P)(*params, *batch, jnp.int32(i))
    tp_new = out[0]

    def obj(tp_):
        a_i = model.actor_forward_ref(P, tp_, obs[:, i, :])
        aj = act.at[:, i, :].set(a_i).reshape(P.batch, -1)
        return float(jnp.mean(model.critic_forward_ref(
            P, tq, obs.reshape(P.batch, -1), aj)))

    assert obj(tp_new) >= obj(tp) - 1e-6


def test_learner_step_linear_in_code_coefficients():
    """The coded recovery premise: every learner computes the SAME
    theta_i'; a coded result sum c_i * theta_i' is therefore exactly
    decodable. Here: two independent evaluations of the step agree
    bitwise, so linear combinations commute with computation."""
    params, batch = make_params(P), make_batch(P)
    step = jax.jit(model.make_learner_step(P))
    thetas = [np.concatenate([np.asarray(x).ravel() for x in step(*params, *batch, jnp.int32(i))[:4]])
              for i in range(P.m)]
    c = np.array([0.3, -1.2, 2.0])
    coded = sum(ci * th for ci, th in zip(c, thetas))
    coded2 = sum(ci * th for ci, th in zip(
        c, [np.concatenate([np.asarray(x).ravel() for x in step(*params, *batch, jnp.int32(i))[:4]])
            for i in range(P.m)]))
    np.testing.assert_array_equal(coded, coded2)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000))
def test_learner_step_outputs_finite(seed):
    params, batch = make_params(P, seed), make_batch(P, seed)
    out = model.make_learner_step_ref(P)(*params, *batch, jnp.int32(seed % P.m))
    for x in out:
        assert np.all(np.isfinite(np.asarray(x)))


# ---------------------------------------------------------------------------
# Stacked actor
# ---------------------------------------------------------------------------


def test_actor_fwd_stacked_matches_per_agent():
    _, _, tpa, _ = make_params(P)
    rng = np.random.default_rng(5)
    obs_all = jnp.asarray(rng.normal(size=(P.m, P.obs_dim)), jnp.float32)
    fwd = jax.jit(model.make_actor_fwd(P))
    acts = fwd(tpa, obs_all)
    assert acts.shape == (P.m, P.act_dim)
    for j in range(P.m):
        single = model.actor_forward(P, tpa[j], obs_all[j:j + 1, :])
        np.testing.assert_allclose(np.asarray(acts[j]), np.asarray(single[0]),
                                   rtol=1e-5, atol=1e-6)
