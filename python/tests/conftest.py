"""Shared fixtures: make `compile` importable when pytest runs from
python/ or from the repo root."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
