"""L1 correctness: Pallas kernels vs pure-jnp reference.

Hypothesis sweeps shapes/dtypes/activations; every case asserts
allclose between compile.kernels.linear (Pallas, interpret=True) and
compile.kernels.ref (plain jnp), forward and backward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linear, ref

ACTS = ref.ACTIVATIONS


def _mk(rng, B, I, O, dtype):
    x = jnp.asarray(rng.normal(size=(B, I)), dtype)
    w = jnp.asarray(rng.normal(size=(I, O)) / np.sqrt(I), dtype)
    b = jnp.asarray(rng.normal(size=(O,)), dtype)
    return x, w, b


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    B=st.integers(1, 96),
    I=st.integers(1, 96),
    O=st.integers(1, 96),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_act_forward_matches_ref(B, I, O, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _mk(rng, B, I, O, jnp.float32)
    got = linear.linear_act(x, w, b, act)
    want = ref.linear_act(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(jnp.float32))


@settings(max_examples=15, deadline=None)
@given(
    B=st.integers(1, 48),
    I=st.integers(1, 48),
    O=st.integers(1, 48),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_act_grads_match_ref(B, I, O, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _mk(rng, B, I, O, jnp.float32)

    def f(layer):
        return lambda x, w, b: jnp.sum(jnp.cos(layer(x, w, b, act)))

    g = jax.grad(f(linear.linear_act), argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f(ref.linear_act), argnums=(0, 1, 2))(x, w, b)
    for a, bb in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ACTS)
def test_dtypes(dtype, act):
    rng = np.random.default_rng(7)
    x, w, b = _mk(rng, 33, 17, 29, dtype)
    got = linear.linear_act(x, w, b, act)
    want = ref.linear_act(x, w, b, act)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("shape", [(1, 1, 1), (128, 128, 128), (256, 130, 3), (5, 440, 64)])
def test_block_boundaries(shape):
    """Exact multiples of the tile size and heavily ragged shapes."""
    B, I, O = shape
    rng = np.random.default_rng(B * 1000 + I * 10 + O)
    x, w, b = _mk(rng, B, I, O, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(linear.linear_act(x, w, b, "tanh")),
        np.asarray(ref.linear_act(x, w, b, "tanh")),
        rtol=1e-5, atol=1e-5,
    )


def test_custom_block_sizes():
    rng = np.random.default_rng(3)
    x, w, b = _mk(rng, 64, 32, 48, jnp.float32)
    for bm, bn in [(8, 8), (16, 64), (128, 128)]:
        got = linear.linear_act(x, w, b, "relu", bm, bn)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.linear_act(x, w, b, "relu")),
            rtol=1e-5, atol=1e-5,
        )


def test_backward_kernel_direct():
    """The raw backward kernels (not just through custom_vjp)."""
    rng = np.random.default_rng(11)
    for act in ACTS:
        x, w, b = _mk(rng, 21, 13, 9, jnp.float32)
        y = ref.linear_act(x, w, b, act)
        g = jnp.asarray(rng.normal(size=y.shape), jnp.float32)
        dx, dw, db = linear._linear_act_bwd_impl(x, w, y, g, act, 128, 128)
        rdx, rdw, rdb = ref.linear_act_bwd(x, w, y, g, act)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(rdx), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(rdw), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(db), np.asarray(rdb), rtol=1e-5, atol=1e-5)


def test_grad_against_finite_differences():
    rng = np.random.default_rng(23)
    x, w, b = _mk(rng, 6, 5, 4, jnp.float32)
    f = lambda w: jnp.sum(linear.linear_act(x, w, b, "tanh"))
    g = np.asarray(jax.grad(f)(w))
    eps = 1e-3
    for (i, j) in [(0, 0), (2, 3), (4, 1)]:
        wp = np.asarray(w).copy(); wp[i, j] += eps
        wm = np.asarray(w).copy(); wm[i, j] -= eps
        fd = (float(f(jnp.asarray(wp))) - float(f(jnp.asarray(wm)))) / (2 * eps)
        np.testing.assert_allclose(g[i, j], fd, rtol=2e-2, atol=2e-3)


def test_vmem_and_mxu_estimates_monotone():
    """Doc-level invariants of the TPU mapping estimators."""
    small = linear.vmem_footprint_bytes(8, 8, 8)
    big = linear.vmem_footprint_bytes(128, 512, 128)
    assert small < big
    assert 0.0 < linear.mxu_utilization_estimate(8, 64, 8) < 1.0
    assert linear.mxu_utilization_estimate(128, 64, 128) == 1.0
