"""AOT lowering: JAX (L2, calling Pallas L1) -> HLO text artifacts.

Emits, for every preset in presets.default_presets():

    artifacts/<name>/learner_step.hlo.txt
    artifacts/<name>/actor_fwd.hlo.txt

plus a single artifacts/manifest.json describing dimensions, parameter
layouts, baked hyperparameters and relative artifact paths. The Rust
runtime (rust/src/runtime/) consumes the manifest and loads the HLO via
`HloModuleProto::from_text_file` on the PJRT CPU client.

HLO *text* is the interchange format, NOT `.serialize()`: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            [--presets quickstart_m3,coop_nav_m8] [--force]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model, presets


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _input_fingerprint() -> str:
    """Hash of the python compile sources — lets `make artifacts` no-op
    when nothing changed (recorded in the manifest)."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def lower_preset(p: presets.Preset, out_dir: str) -> dict:
    os.makedirs(os.path.join(out_dir, p.name), exist_ok=True)
    entry = p.manifest_entry()
    t0 = time.time()

    step = model.make_learner_step(p)
    lowered = jax.jit(step).lower(*model.learner_step_arg_specs(p))
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, entry["artifacts"]["learner_step"]), "w") as f:
        f.write(text)
    ls_bytes = len(text)

    fwd = model.make_actor_fwd(p)
    lowered = jax.jit(fwd).lower(*model.actor_fwd_arg_specs(p))
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, entry["artifacts"]["actor_fwd"]), "w") as f:
        f.write(text)

    entry["hlo_bytes"] = {"learner_step": ls_bytes, "actor_fwd": len(text)}
    entry["lower_seconds"] = round(time.time() - t0, 2)
    print(f"  {p.name}: learner_step {ls_bytes/1e6:.2f} MB, "
          f"actor_fwd {len(text)/1e3:.0f} KB, {entry['lower_seconds']}s",
          flush=True)
    return entry


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="",
                    help="comma-separated preset names (default: all)")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the manifest fingerprint matches")
    args = ap.parse_args()

    want = [s for s in args.presets.split(",") if s]
    plist = presets.default_presets()
    if want:
        plist = [p for p in plist if p.name in want]
        missing = set(want) - {p.name for p in plist}
        if missing:
            print(f"unknown presets: {sorted(missing)}", file=sys.stderr)
            return 2

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    fp = _input_fingerprint()
    if not args.force and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        have = {e["name"] for e in old.get("presets", [])}
        if old.get("fingerprint") == fp and {p.name for p in plist} <= have:
            print(f"artifacts up to date (fingerprint {fp[:12]}), nothing to do")
            return 0

    print(f"lowering {len(plist)} preset(s) -> {args.out_dir}")
    entries = [lower_preset(p, args.out_dir) for p in plist]

    # Merge with any presets already present but not re-lowered this run.
    if os.path.exists(manifest_path) and want:
        with open(manifest_path) as f:
            old = json.load(f)
        names = {e["name"] for e in entries}
        entries += [e for e in old.get("presets", []) if e["name"] not in names]

    manifest = {
        "format_version": 1,
        "fingerprint": fp,
        "jax_version": jax.__version__,
        "interchange": "hlo_text",
        "presets": entries,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
