"""Experiment presets — the single source of truth for model dimensions.

Every artifact set (one per preset) is described here; aot.py lowers each
preset to HLO and records the exact numbers in artifacts/manifest.json,
which the Rust side (rust/src/runtime/manifest.rs) parses. The Rust
environments must emit observations of exactly `obs_dim(env, M)` floats
per agent — the formulas here and in rust/src/env/mod.rs must agree
(python/tests/test_presets.py pins them).

Observation layouts (2-D world, all vectors relative to self unless
noted):

* coop_nav:        [self_vel(2), self_pos(2), landmarks(2M), others(2(M-1))]
* predator_prey:   [self_vel(2), self_pos(2), obstacles(2*2),
                    others_pos(2(M-1)), others_vel(2(M-1))]
* deception:       [self_vel(2), self_pos(2), landmarks(2*2),
                    others(2(M-1)), target(2; zeroed for adversaries)]
* keep_away:       same layout as deception

Actions are continuous 2-D forces in [-1, 1]^2 (tanh policy head).
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict
from typing import Dict, List

HIDDEN = 64
ACT_DIM = 2
N_OBSTACLES = 2  # predator_prey
N_LANDMARKS_DECEPTION = 2  # deception / keep_away

ENVS = ("coop_nav", "predator_prey", "deception", "keep_away")


def obs_dim(env: str, m: int) -> int:
    """Per-agent observation dimension (uniform across agents)."""
    if env == "coop_nav":
        return 4 + 2 * m + 2 * (m - 1)
    if env == "predator_prey":
        return 4 + 2 * N_OBSTACLES + 4 * (m - 1)
    if env in ("deception", "keep_away"):
        return 4 + 2 * N_LANDMARKS_DECEPTION + 2 * (m - 1) + 2
    raise ValueError(f"unknown env {env!r}")


@dataclass(frozen=True)
class Preset:
    """One lowered artifact configuration.

    All hyperparameters that are baked into the HLO as constants live
    here (gamma/tau/lrs); anything runtime-tunable (N learners, coding
    scheme, straggler model) lives on the Rust side.
    """

    name: str
    env: str
    m: int                      # number of agents M
    n_adversaries: int          # K (0 for cooperative envs)
    batch: int = 32             # minibatch size B
    hidden: int = HIDDEN
    act_dim: int = ACT_DIM
    gamma: float = 0.95
    tau: float = 0.99           # Polyak per paper Eq. (5): th^ <- tau*th^ + (1-tau)*th
    lr_actor: float = 1e-3
    lr_critic: float = 1e-2

    @property
    def obs_dim(self) -> int:
        return obs_dim(self.env, self.m)

    @property
    def critic_in_dim(self) -> int:
        return self.m * (self.obs_dim + self.act_dim)

    @property
    def actor_param_dim(self) -> int:
        d, h, a = self.obs_dim, self.hidden, self.act_dim
        return (d * h + h) + (h * h + h) + (h * a + a)

    @property
    def critic_param_dim(self) -> int:
        c, h = self.critic_in_dim, self.hidden
        return (c * h + h) + (h * h + h) + (h * 1 + 1)

    @property
    def agent_param_dim(self) -> int:
        """theta_i = [theta_p, theta_q, theta_p_hat, theta_q_hat]."""
        return 2 * (self.actor_param_dim + self.critic_param_dim)

    def manifest_entry(self) -> Dict:
        d = asdict(self)
        d.update(
            obs_dim=self.obs_dim,
            critic_in_dim=self.critic_in_dim,
            actor_param_dim=self.actor_param_dim,
            critic_param_dim=self.critic_param_dim,
            agent_param_dim=self.agent_param_dim,
            artifacts={
                "learner_step": f"{self.name}/learner_step.hlo.txt",
                "actor_fwd": f"{self.name}/actor_fwd.hlo.txt",
            },
        )
        return d


def default_presets() -> List[Preset]:
    """The artifact sets the experiments need.

    * quickstart: tiny coop_nav for examples/tests (fast lowering+exec)
    * one preset per (env, M in {8, 10}) for Figs. 3-5; K=4 adversaries
      in the competitive envs per paper SsV-B.
    """
    out = [Preset(name="quickstart_m3", env="coop_nav", m=3, n_adversaries=0)]
    for m in (8, 10):
        out.append(Preset(name=f"coop_nav_m{m}", env="coop_nav", m=m, n_adversaries=0))
        out.append(Preset(name=f"predator_prey_m{m}", env="predator_prey", m=m, n_adversaries=4))
        out.append(Preset(name=f"deception_m{m}", env="deception", m=m, n_adversaries=4))
        out.append(Preset(name=f"keep_away_m{m}", env="keep_away", m=m, n_adversaries=4))
    return out


def preset_by_name(name: str) -> Preset:
    for p in default_presets():
        if p.name == name:
            return p
    raise KeyError(name)
