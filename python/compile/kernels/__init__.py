"""Pallas kernels (L1) + pure-jnp reference oracle.

Import surface used by model.py:
    from .kernels import linear, ref
    linear.linear_act(x, w, b, act="tanh")
"""

from . import linear, ref  # noqa: F401
