"""L1 — Pallas fused linear-layer kernels.

The compute hot spot of MADDPG training is the dense GEMM inside every
actor/critic MLP layer, on both the forward and backward pass. This
module implements it as a Pallas kernel family:

* ``linear_act(x, w, b, act)``     — fused ``act(x @ w + b)`` forward
* backward kernels for dx / dw (+db fused into dw's epilogue)

and wires them together with ``jax.custom_vjp`` so the L2 model code can
simply call :func:`linear_act` and get Pallas on both passes.

TPU mapping (see DESIGN.md §3): the GEMM is tiled with ``BlockSpec`` so
each grid step streams an (bm × K)·(K × bn) panel pair through VMEM and
the MXU, and the bias add + activation are fused into the epilogue so
the accumulator never round-trips to HBM between GEMM and activation.
On this image the kernels always run ``interpret=True`` — the CPU PJRT
plugin cannot execute Mosaic custom-calls — so block shapes matter for
the *lowered structure* (documented VMEM/MXU estimates), not CPU speed.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default MXU-aligned tile sizes. 128 matches the TPU systolic array; the
# wrapper pads ragged dims up to the block size (blocks are clamped to the
# padded problem size so tiny layers don't blow up 128x).
DEFAULT_BM = 128
DEFAULT_BN = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_block(dim: int, default: int) -> int:
    """Clamp the default block size to the (padded) problem dimension."""
    return min(default, _round_up(dim, 8))


# ---------------------------------------------------------------------------
# Forward kernel: y = act(x @ w + b)
# ---------------------------------------------------------------------------


def _linear_act_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    """One (bm, bn) output tile: full-K GEMM panel + fused epilogue.

    x_ref: [bm, K] VMEM block, w_ref: [K, bn], b_ref: [1, bn].
    Accumulation is forced to f32 via preferred_element_type so bf16
    inputs still hit the MXU's f32 accumulator.
    """
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)
    o_ref[...] = ref.activate(acc, act).astype(o_ref.dtype)


def _linear_act_fwd_impl(x, w, b, act: str, bm: int, bn: int):
    B, K = x.shape
    _, O = w.shape
    bm = _pick_block(B, bm)
    bn = _pick_block(O, bn)
    Bp, Op = _round_up(B, bm), _round_up(O, bn)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0))) if Bp != B else x
    wp = jnp.pad(w, ((0, 0), (0, Op - O))) if Op != O else w
    bp = (jnp.pad(b, (0, Op - O)) if Op != O else b).reshape(1, Op)

    out = pl.pallas_call(
        functools.partial(_linear_act_kernel, act=act),
        grid=(Bp // bm, Op // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Op), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, bp)
    return out[:B, :O]


# ---------------------------------------------------------------------------
# Backward kernels.
#
# gz = g * act'(y) is computed inside each kernel from the saved output y
# (cheaper than stashing pre-activations: tanh' = 1-y^2, relu' = 1[y>0]).
# dx = gz @ w^T   — tiled over (B, I)
# dw = x^T @ gz   — tiled over (I, O); db = sum_B gz fused as an extra row
# ---------------------------------------------------------------------------


def _dx_kernel(g_ref, y_ref, w_ref, o_ref, *, act: str):
    gz = g_ref[...].astype(jnp.float32) * ref.activate_grad(
        y_ref[...].astype(jnp.float32), act
    )
    o_ref[...] = jnp.dot(
        gz, w_ref[...].T, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _dwdb_kernel(x_ref, g_ref, y_ref, o_ref, *, act: str):
    gz = g_ref[...].astype(jnp.float32) * ref.activate_grad(
        y_ref[...].astype(jnp.float32), act
    )
    dw = jnp.dot(x_ref[...].T, gz, preferred_element_type=jnp.float32)
    db = jnp.sum(gz, axis=0, keepdims=True)
    # Row 0..I-1: dw block; row I: db block (fused epilogue, one output).
    o_ref[...] = jnp.concatenate([dw, db], axis=0).astype(o_ref.dtype)


def _linear_act_bwd_impl(x, w, y, g, act: str, bm: int, bn: int):
    B, K = x.shape
    _, O = w.shape

    # dx: grid over (B, I) tiles, full-O contraction per tile.
    bmx = _pick_block(B, bm)
    bkx = _pick_block(K, bn)
    Bp, Kp = _round_up(B, bmx), _round_up(K, bkx)
    gp = jnp.pad(g, ((0, Bp - B), (0, 0))) if Bp != B else g
    yp = jnp.pad(y, ((0, Bp - B), (0, 0))) if Bp != B else y
    wp = jnp.pad(w, ((0, Kp - K), (0, 0))) if Kp != K else w
    dx = pl.pallas_call(
        functools.partial(_dx_kernel, act=act),
        grid=(Bp // bmx, Kp // bkx),
        in_specs=[
            pl.BlockSpec((bmx, O), lambda i, j: (i, 0)),
            pl.BlockSpec((bmx, O), lambda i, j: (i, 0)),
            pl.BlockSpec((bkx, O), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bmx, bkx), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Kp), x.dtype),
        interpret=True,
    )(gp, yp, wp)[:B, :K]

    # dw (+db): grid over (I, O) tiles, full-B contraction per tile. The
    # output carries one extra row per I-tile holding the partial db; only
    # the first I-tile's extra row is the real db (others see padded x=0
    # contributions... no: db = sum over B of gz, independent of I). We
    # compute db in every j-tile redundantly and read it from i=0.
    bki = _pick_block(K, bm)
    bnj = _pick_block(O, bn)
    Kp2, Op2 = _round_up(K, bki), _round_up(O, bnj)
    xp = jnp.pad(x, ((0, 0), (0, Kp2 - K))) if Kp2 != K else x
    gp2 = jnp.pad(g, ((0, 0), (0, Op2 - O))) if Op2 != O else g
    yp2 = jnp.pad(y, ((0, 0), (0, Op2 - O))) if Op2 != O else y
    dwdb = pl.pallas_call(
        functools.partial(_dwdb_kernel, act=act),
        grid=(Kp2 // bki, Op2 // bnj),
        in_specs=[
            pl.BlockSpec((B, bki), lambda i, j: (0, i)),
            pl.BlockSpec((B, bnj), lambda i, j: (0, j)),
            pl.BlockSpec((B, bnj), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bki + 1, bnj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Kp2 + Kp2 // bki, Op2), w.dtype),
        interpret=True,
    )(xp, gp2, yp2)
    # Un-interleave: each i-tile contributed bki rows of dw + 1 row of db.
    dwdb = dwdb.reshape(Kp2 // bki, bki + 1, Op2)
    dw = dwdb[:, :bki, :].reshape(Kp2, Op2)[:K, :O]
    db = dwdb[0, bki, :O]
    return dx, dw, db


# ---------------------------------------------------------------------------
# custom_vjp wrapper — the public entry point used by model.py
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def linear_act(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    act: str = "none",
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
) -> jnp.ndarray:
    """Fused linear layer ``act(x @ w + b)`` backed by Pallas kernels.

    Differentiable (custom VJP; the backward pass is also Pallas).
    x: [B, I] float32/bfloat16, w: [I, O], b: [O].
    """
    return _linear_act_fwd_impl(x, w, b, act, bm, bn)


def _vjp_fwd(x, w, b, act, bm, bn):
    y = _linear_act_fwd_impl(x, w, b, act, bm, bn)
    return y, (x, w, y)


def _vjp_bwd(act, bm, bn, res, g):
    x, w, y = res
    dx, dw, db = _linear_act_bwd_impl(x, w, y, g, act, bm, bn)
    return dx, dw, db


linear_act.defvjp(_vjp_fwd, _vjp_bwd)


def vmem_footprint_bytes(
    B: int, I: int, O: int, dtype_bytes: int = 4,
    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
) -> int:
    """Estimated VMEM working set of one forward grid step.

    x panel (bm, I) + w panel (I, bn) + bias (1, bn) + f32 accumulator
    (bm, bn). Used by DESIGN/EXPERIMENTS to document the TPU mapping
    (interpret=True gives no real device telemetry).
    """
    bm = _pick_block(B, bm)
    bn = _pick_block(O, bn)
    return (bm * I + I * bn + bn) * dtype_bytes + bm * bn * 4


def mxu_utilization_estimate(
    B: int, I: int, O: int, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN
) -> float:
    """Fraction of MXU lanes doing useful work (ignores pipeline ramp).

    The 128x128 systolic array is fully fed only when the tile dims reach
    128; smaller problems waste lanes proportionally.
    """
    bm = _pick_block(B, bm)
    bn = _pick_block(O, bn)
    eff_m = min(B, bm) / max(bm, 128) * min(bm, 128) / 128
    eff_n = min(O, bn) / max(bn, 128) * min(bn, 128) / 128
    # Guard: effective fraction of a 128-lane dim actually occupied.
    eff_m = min(1.0, min(B, 128) / 128)
    eff_n = min(1.0, min(O, 128) / 128)
    return eff_m * eff_n
