"""Pure-jnp reference oracle for the Pallas kernels.

Every Pallas kernel in this package has a line-for-line mathematical
twin here. The pytest suite (python/tests/test_kernels.py) sweeps
shapes/dtypes with hypothesis and asserts allclose between the two.
These references are also reused by model.py's `*_ref` functions so the
whole L2 learner step can be checked end-to-end against plain jnp.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Activation tags shared with the Pallas kernels. Kept as plain strings
#: (not an enum) so they can be embedded in artifact manifests verbatim.
ACTIVATIONS = ("none", "tanh", "relu")


def activate(x: jnp.ndarray, act: str) -> jnp.ndarray:
    """Apply the activation named ``act`` (one of ACTIVATIONS)."""
    if act == "none":
        return x
    if act == "tanh":
        return jnp.tanh(x)
    if act == "relu":
        return jnp.maximum(x, 0.0)
    raise ValueError(f"unknown activation {act!r}")


def activate_grad(y: jnp.ndarray, act: str) -> jnp.ndarray:
    """d act(z) / d z expressed in terms of the *output* y = act(z).

    Using the output (rather than the pre-activation) lets the backward
    kernels avoid stashing z: tanh' = 1 - y**2, relu' = 1[y > 0].
    """
    if act == "none":
        return jnp.ones_like(y)
    if act == "tanh":
        return 1.0 - y * y
    if act == "relu":
        return (y > 0).astype(y.dtype)
    raise ValueError(f"unknown activation {act!r}")


def linear_act(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, act: str = "none") -> jnp.ndarray:
    """Reference fused linear layer: ``act(x @ w + b)``.

    x: [B, I], w: [I, O], b: [O] -> [B, O]. Accumulates in f32.
    """
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    return activate(y, act).astype(x.dtype)


def linear_act_bwd(x, w, y, g, act: str):
    """Reference backward pass for linear_act.

    Given y = act(x@w + b) and upstream cotangent g, returns
    (dx, dw, db) — the same quantities the Pallas backward kernels
    produce.
    """
    gz = (g.astype(jnp.float32) * activate_grad(y.astype(jnp.float32), act))
    dx = jnp.dot(gz, w.astype(jnp.float32).T).astype(x.dtype)
    dw = jnp.dot(x.astype(jnp.float32).T, gz).astype(w.dtype)
    db = jnp.sum(gz, axis=0).astype(w.dtype)
    return dx, dw, db
