"""L2 — MADDPG model: actor/critic forward + the per-agent learner step.

This is the compute graph that each *learner* executes for each agent
assigned to it (paper Alg. 1, lines 21-24):

  1. critic update   — minimize the TD error, Eq. (3)
  2. policy update   — deterministic policy gradient ascent, Eq. (4)
  3. target updates  — Polyak averaging, Eq. (5)

Everything is a pure function of (parameters, minibatch) so that the
coded recovery of Eq. (2) is exact: the controller can linearly combine
learner outputs because each learner computes exactly the same
theta_i' = f(theta, batch) for its assigned agents.

All dense layers go through the Pallas kernel
(:func:`compile.kernels.linear.linear_act`) on both the forward and
backward pass; `*_ref` twins use plain jnp for the pytest oracle.

Parameter layout (flat f32 vectors; mirrored by rust/src/marl/params.rs):

  actor  theta_p = [W1(Do*H) | b1(H) | W2(H*H) | b2(H) | W3(H*Da) | b3(Da)]
  critic theta_q = [W1(Dc*H) | b1(H) | W2(H*H) | b2(H) | W3(H*1) | b3(1)]

with matrices stored row-major and Dc = M*(Do+Da).
"""

from __future__ import annotations

import functools
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import linear, ref
from .presets import Preset


# ---------------------------------------------------------------------------
# Parameter packing
# ---------------------------------------------------------------------------


def mlp_shapes(in_dim: int, hidden: int, out_dim: int) -> List[Tuple[int, ...]]:
    """Shapes of the 3-layer MLP parameter blocks, in flat-layout order."""
    return [
        (in_dim, hidden), (hidden,),
        (hidden, hidden), (hidden,),
        (hidden, out_dim), (out_dim,),
    ]


def param_dim(shapes: List[Tuple[int, ...]]) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for s in shapes)


def unpack(flat: jnp.ndarray, shapes: List[Tuple[int, ...]]) -> List[jnp.ndarray]:
    """Split a flat parameter vector into the per-block arrays."""
    out, off = [], 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        out.append(flat[off:off + n].reshape(s))
        off += n
    assert off == flat.shape[0], (off, flat.shape)
    return out


def pack(blocks: List[jnp.ndarray]) -> jnp.ndarray:
    return jnp.concatenate([b.reshape(-1) for b in blocks])


def init_mlp(key: jax.Array, shapes: List[Tuple[int, ...]]) -> jnp.ndarray:
    """Glorot-uniform weights, zero biases, packed flat.

    Mirrored bit-for-bit is not required on the Rust side (Rust owns
    initialization via its own RNG); this initializer exists for python
    tests and the pure-python training sanity check.
    """
    blocks = []
    for s in shapes:
        if len(s) == 2:
            key, sub = jax.random.split(key)
            limit = (6.0 / (s[0] + s[1])) ** 0.5
            blocks.append(jax.random.uniform(sub, s, jnp.float32, -limit, limit))
        else:
            blocks.append(jnp.zeros(s, jnp.float32))
    return pack(blocks)


# ---------------------------------------------------------------------------
# Forward passes (Pallas-backed and reference)
# ---------------------------------------------------------------------------


def _mlp_forward(
    flat: jnp.ndarray,
    x: jnp.ndarray,
    shapes: List[Tuple[int, ...]],
    acts: Tuple[str, str, str],
    layer: Callable = linear.linear_act,
) -> jnp.ndarray:
    w1, b1, w2, b2, w3, b3 = unpack(flat, shapes)
    h = layer(x, w1, b1, acts[0])
    h = layer(h, w2, b2, acts[1])
    return layer(h, w3, b3, acts[2])


def actor_forward(p: Preset, theta_p: jnp.ndarray, obs: jnp.ndarray,
                  layer: Callable = linear.linear_act) -> jnp.ndarray:
    """Deterministic policy pi_i(s_i): obs [B, Do] -> action [B, Da] in [-1,1]."""
    shapes = mlp_shapes(p.obs_dim, p.hidden, p.act_dim)
    return _mlp_forward(theta_p, obs, shapes, ("tanh", "tanh", "tanh"), layer)


def critic_forward(p: Preset, theta_q: jnp.ndarray, obs_joint: jnp.ndarray,
                   act_joint: jnp.ndarray,
                   layer: Callable = linear.linear_act) -> jnp.ndarray:
    """Centralized Q_i(s, a): [B, M*Do], [B, M*Da] -> [B]."""
    shapes = mlp_shapes(p.critic_in_dim, p.hidden, 1)
    x = jnp.concatenate([obs_joint, act_joint], axis=1)
    return _mlp_forward(theta_q, x, shapes, ("tanh", "tanh", "none"), layer)[:, 0]


def actor_forward_ref(p, theta_p, obs):
    return actor_forward(p, theta_p, obs, layer=ref.linear_act)


def critic_forward_ref(p, theta_q, obs_joint, act_joint):
    return critic_forward(p, theta_q, obs_joint, act_joint, layer=ref.linear_act)


# ---------------------------------------------------------------------------
# Learner step (the artifact Rust executes per assigned agent)
# ---------------------------------------------------------------------------


def make_learner_step(p: Preset, layer: Callable = linear.linear_act):
    """Build learner_step(theta_p_i, theta_q_i, tpol_all, theta_q_hat_i,
    obs, act, rew, obs2, done, agent_idx) for preset ``p``.

    Shapes:
      theta_p_i    [Pp]          current policy of agent i
      theta_q_i    [Pq]          current critic of agent i
      tpol_all     [M, Pp]       target policies of ALL agents
      theta_q_hat  [Pq]          target critic of agent i
      obs, obs2    [B, M, Do]    joint observations (s, s')
      act          [B, M, Da]    joint actions from the replay buffer
      rew, done    [B]           agent-i reward, terminal mask
      agent_idx    i32 scalar    which agent this invocation updates

    Returns (theta_p', theta_q', theta_p_hat', theta_q_hat',
             critic_loss, pg_objective).
    """
    B, M = p.batch, p.m

    def learner_step(theta_p, theta_q, tpol_all, theta_q_hat,
                     obs, act, rew, obs2, done, agent_idx):
        obs_joint = obs.reshape(B, -1)
        act_joint = act.reshape(B, -1)
        obs2_joint = obs2.reshape(B, -1)

        # --- critic target: a' = (pi_hat_1(s'_1), ..., pi_hat_M(s'_M)).
        # Static python loop over agents: M is compile-time, and looping
        # avoids vmap-of-pallas corner cases in the lowered HLO.
        a2 = [actor_forward(p, tpol_all[j], obs2[:, j, :], layer) for j in range(M)]
        a2_joint = jnp.concatenate(a2, axis=1)
        q_next = critic_forward(p, theta_q_hat, obs2_joint, a2_joint, layer)
        target = rew + p.gamma * (1.0 - done) * q_next
        target = jax.lax.stop_gradient(target)

        # --- critic update: minimize TD error, Eq. (3).
        def critic_loss_fn(tq):
            q = critic_forward(p, tq, obs_joint, act_joint, layer)
            return jnp.mean((target - q) ** 2)

        critic_loss, g_q = jax.value_and_grad(critic_loss_fn)(theta_q)
        theta_q_new = theta_q - p.lr_critic * g_q

        # --- policy update: deterministic policy gradient, Eq. (4).
        # Replace agent i's replayed action with pi_i(s_i; theta_p); other
        # agents' actions stay as sampled (MADDPG surrogate).
        obs_i = jnp.take(obs, agent_idx, axis=1)  # [B, Do]

        def pg_objective_fn(tp):
            a_i = actor_forward(p, tp, obs_i, layer)  # [B, Da]
            a_joint = jax.lax.dynamic_update_slice(
                act, a_i[:, None, :], (0, agent_idx, 0)
            ).reshape(B, -1)
            return jnp.mean(critic_forward(p, theta_q, obs_joint, a_joint, layer))

        pg_obj, g_p = jax.value_and_grad(pg_objective_fn)(theta_p)
        theta_p_new = theta_p + p.lr_actor * g_p

        # --- target updates: Polyak averaging, Eq. (5) (paper's form:
        # theta_hat <- tau*theta_hat + (1-tau)*theta, tau close to 1).
        theta_p_hat = jnp.take(tpol_all, agent_idx, axis=0)
        theta_p_hat_new = p.tau * theta_p_hat + (1.0 - p.tau) * theta_p_new
        theta_q_hat_new = p.tau * theta_q_hat + (1.0 - p.tau) * theta_q_new

        return (theta_p_new, theta_q_new, theta_p_hat_new, theta_q_hat_new,
                critic_loss, pg_obj)

    return learner_step


def make_learner_step_ref(p: Preset):
    """Pure-jnp twin of make_learner_step (pytest oracle)."""
    return make_learner_step(p, layer=ref.linear_act)


# ---------------------------------------------------------------------------
# Stacked actor forward (rollout-path artifact)
# ---------------------------------------------------------------------------


def make_actor_fwd(p: Preset, layer: Callable = linear.linear_act):
    """actor_fwd(theta_p_all [M,Pp], obs_all [M,Do]) -> actions [M,Da].

    One PJRT dispatch computes all M agents' actions for a single joint
    observation (used by the controller when collecting episodes; the
    Rust rollout path also has a native MLP forward verified against
    this artifact).
    """
    M = p.m

    def actor_fwd(theta_p_all, obs_all):
        outs = [actor_forward(p, theta_p_all[j], obs_all[j:j + 1, :], layer)
                for j in range(M)]
        return jnp.concatenate(outs, axis=0)

    return actor_fwd


# ---------------------------------------------------------------------------
# Example-argument builders (shared by aot.py and the tests)
# ---------------------------------------------------------------------------


def learner_step_arg_specs(p: Preset):
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct
    B, M = p.batch, p.m
    return (
        S((p.actor_param_dim,), f32),
        S((p.critic_param_dim,), f32),
        S((M, p.actor_param_dim), f32),
        S((p.critic_param_dim,), f32),
        S((B, M, p.obs_dim), f32),
        S((B, M, p.act_dim), f32),
        S((B,), f32),
        S((B, M, p.obs_dim), f32),
        S((B,), f32),
        S((), i32),
    )


def actor_fwd_arg_specs(p: Preset):
    S, f32 = jax.ShapeDtypeStruct, jnp.float32
    return (
        S((p.m, p.actor_param_dim), f32),
        S((p.m, p.obs_dim), f32),
    )
